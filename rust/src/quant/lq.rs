//! LQSGD — the paper's practical lattice quantizer (Section 9.1).
//!
//! Encoder: round to the nearest point of a shared-randomly-offset cubic
//! lattice, transmit the coordinate-wise index mod q (`⌈d·log₂ q⌉` bits,
//! bit-packed). Decoder: nearest same-color lattice point to its own
//! vector. Unbiasedness comes from the shared random offset; decode is
//! exact whenever `‖x_u − x_v‖∞ ≤ (q−1)s/2`.

use super::bits::width_for;
use super::lattice::{side_for_y, CubicLattice};
use super::{Message, VectorCodec};
use crate::rng::Rng;

/// The LQSGD codec. One instance per round (the offset is per-round shared
/// randomness); `q` and `s` are fixed at construction.
#[derive(Clone, Debug)]
pub struct LatticeQuantizer {
    pub lattice: CubicLattice,
    pub q: u32,
    width: u32,
}

impl LatticeQuantizer {
    /// From an explicit lattice.
    pub fn new(lattice: CubicLattice, q: u32) -> Self {
        assert!(q >= 2, "need at least 2 colors");
        let width = width_for(q as u64);
        LatticeQuantizer { lattice, q, width }
    }

    /// The paper's parameterization: given a distance bound `y` (ℓ∞),
    /// choose `s = 2y/(q−1)` and a shared-random offset.
    pub fn from_y(d: usize, q: u32, y: f64, shared: &mut Rng) -> Self {
        let s = side_for_y(y.max(f64::MIN_POSITIVE), q);
        Self::new(CubicLattice::random_offset(d, s, shared), q)
    }

    /// Deterministic variant used by tests (offset 0).
    pub fn centered(d: usize, q: u32, s: f64) -> Self {
        Self::new(CubicLattice::centered(d, s), q)
    }

    /// Exact message size for this codec: `d · ⌈log₂ q⌉` bits.
    pub fn message_bits(&self) -> u64 {
        self.lattice.dim() as u64 * self.width as u64
    }

    /// The shared fused decode loop: colors for coordinates
    /// `lo..lo + len` are pulled through the word-granular block kernel
    /// ([`super::bits::BitReader::read_block`], one unaligned load per
    /// ⌊64/width⌋ colors) and each reconstructed coordinate is handed to
    /// `emit(index, value)`. Every decode entry point (`decode_into`,
    /// `decode_accumulate_into`, `decode_accumulate_range`) is this loop
    /// with a different sink, so they are value-identical by
    /// construction.
    fn decode_fold(
        &self,
        msg: &Message,
        reference: &[f64],
        lo: usize,
        len: usize,
        mut emit: impl FnMut(usize, f64),
    ) {
        const BLOCK: usize = 128;
        let s = self.lattice.s;
        // Fold the two divisions into one reciprocal multiply each
        // (§Perf): t/q = (x−off) · (1/(s·q)).
        let inv_sq = 1.0 / (s * self.q as f64);
        let inv_q = 1.0 / self.q as f64;
        let qi = self.q as i64;
        let width = self.width;
        let mut r = super::bits::BitReader::new(&msg.bytes);
        r.seek(lo as u64 * width as u64);
        let mut colors = [0u64; BLOCK];
        let mut cf = [0.0f64; BLOCK];
        let mut mf = [0.0f64; BLOCK];
        let mut done = 0;
        while done < len {
            let take = (len - done).min(BLOCK);
            let base = lo + done;
            r.read_block(width, &mut colors[..take]);
            // Vector stage (§Perf): the congruence solve runs through
            // [`crate::simd::fold_decode_indices`] on an f64 staging of
            // the colors — exact, since every color is < q ≤ 2³² < 2⁵³ —
            // leaving only the integer cast and the emit scalar.
            for (c, &cu) in cf[..take].iter_mut().zip(&colors[..take]) {
                *c = cu as f64;
            }
            crate::simd::fold_decode_indices(
                &reference[base..base + take],
                &self.lattice.offset[base..base + take],
                &cf[..take],
                inv_sq,
                inv_q,
                &mut mf[..take],
            );
            for (i, (&cu, &m)) in colors[..take].iter().zip(&mf[..take]).enumerate() {
                let idx = base + i;
                let k = cu as i64 + qi * m as i64;
                emit(idx, self.lattice.offset[idx] + s * k as f64);
            }
            done += take;
        }
    }

    /// The shared fused encode loop — the write-side twin of
    /// [`Self::decode_fold`]: coordinates `lo..lo + len` are rounded to
    /// their lattice index (reciprocal-folded, §Perf), reduced to their
    /// color (a mask when `q` is a power of two — the branch is hoisted
    /// to block granularity, never per coordinate), gathered into a
    /// block, and packed through the word-granular write kernel
    /// [`super::bits::BitWriter::push_block`] (one accumulator store per
    /// ⌊64/width⌋ colors). Every encode entry point (`encode`,
    /// `encode_into`, `encode_with_point`, `encode_range`) is this loop
    /// with a different `emit` sink, so they are bit-identical by
    /// construction.
    fn encode_fold(
        &self,
        x: &[f64],
        lo: usize,
        len: usize,
        w: &mut super::bits::BitWriter,
        mut emit: impl FnMut(usize, i64),
    ) {
        const BLOCK: usize = 128;
        let inv = self.lattice.inv_s();
        let width = self.width;
        let mut colors = [0u64; BLOCK];
        let mut kf = [0.0f64; BLOCK];
        let pow2 = (self.q & (self.q - 1)) == 0;
        let mask = (self.q - 1) as i64;
        let q = self.q as i64;
        let offset = &self.lattice.offset;
        let mut done = 0;
        while done < len {
            let take = (len - done).min(BLOCK);
            let base = lo + done;
            // Vector stage (§Perf): the stochastic-rounding arithmetic —
            // offset, scale, round-ties-even — runs through
            // [`crate::simd::quantize_scaled`]; the scalar stage below
            // consumes those exact f64 indices, so staging changes no bit.
            crate::simd::quantize_scaled(
                &x[base..base + take],
                &offset[base..base + take],
                inv,
                &mut kf[..take],
            );
            if pow2 {
                // Two's-complement arithmetic makes the mask correct for
                // negative indices.
                for (j, c) in colors[..take].iter_mut().enumerate() {
                    let k = kf[j] as i64;
                    *c = (k & mask) as u64;
                    emit(base + j, k);
                }
            } else {
                for (j, c) in colors[..take].iter_mut().enumerate() {
                    let k = kf[j] as i64;
                    *c = k.rem_euclid(q) as u64;
                    emit(base + j, k);
                }
            }
            w.push_block(&colors[..take], width);
            done += take;
        }
    }

    /// Encode and also return the quantized point Q(x) (the nearest
    /// lattice point) — used by the experiments' y-estimation policies,
    /// which measure `‖Q(g₀) − Q(g₁)‖∞` (Section 9.2 Exp 2).
    ///
    /// Single fused pass (§Perf): the block kernel [`Self::encode_fold`]
    /// with a point-reconstruction sink, no intermediate index/color
    /// vectors.
    pub fn encode_with_point(&self, x: &[f64]) -> (Message, Vec<f64>) {
        let d = self.lattice.dim();
        assert_eq!(x.len(), d);
        let s = self.lattice.s;
        let offset = &self.lattice.offset;
        let mut w = super::bits::BitWriter::with_capacity(d * self.width as usize);
        let mut point = vec![0.0; d];
        self.encode_fold(x, 0, d, &mut w, |idx, k| {
            point[idx] = offset[idx] + s * k as f64;
        });
        let (bytes, bits) = w.finish();
        (Message { bytes, bits }, point)
    }
}

impl VectorCodec for LatticeQuantizer {
    fn name(&self) -> String {
        format!("LQSGD(q={})", self.q)
    }

    fn dim(&self) -> usize {
        self.lattice.dim()
    }

    /// Deterministic given the (shared-random) offset; `_rng` unused.
    /// Same block kernel as `encode_into`, minus the point sink the
    /// y-estimation paths pay for in [`Self::encode_with_point`].
    fn encode(&mut self, x: &[f64], _rng: &mut Rng) -> Message {
        let d = self.lattice.dim();
        assert_eq!(x.len(), d);
        let mut w = super::bits::BitWriter::with_capacity(d * self.width as usize);
        self.encode_fold(x, 0, d, &mut w, |_, _| {});
        let (bytes, bits) = w.finish();
        Message { bytes, bits }
    }

    /// Fused decode (§Perf): bit-read → nearest-same-color → reconstruct
    /// per coordinate, single pass.
    fn decode(&self, msg: &Message, reference: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.lattice.dim()];
        self.decode_into(msg, reference, &mut out);
        out
    }

    /// Zero-alloc encode: the block kernel [`Self::encode_fold`] minus
    /// the point reconstruction, writing into the recycled scratch.
    fn encode_into(&mut self, x: &[f64], _rng: &mut Rng, out: &mut Message) {
        let d = self.lattice.dim();
        assert_eq!(x.len(), d);
        let mut w = super::bits::BitWriter::reusing(std::mem::take(&mut out.bytes));
        self.encode_fold(x, 0, d, &mut w, |_, _| {});
        let (bytes, bits) = w.finish();
        out.bytes = bytes;
        out.bits = bits;
    }

    /// Chunk kernel for the parallel encode
    /// ([`crate::quant::encode_chunked`]): appends exactly the fields for
    /// coordinates `lo..lo + len` — a fixed-width stream, so the caller
    /// can stitch byte-aligned chunks back together bit-identically.
    fn encode_range(&self, x: &[f64], lo: usize, len: usize, w: &mut super::bits::BitWriter) {
        assert_eq!(x.len(), self.lattice.dim());
        assert!(lo + len <= x.len());
        self.encode_fold(x, lo, len, w, |_, _| {});
    }

    fn supports_encode_range(&self) -> bool {
        true
    }

    /// Coordinates per byte-aligned chunk quantum: `8/gcd(width, 8)`
    /// fields fill a whole number of bytes.
    fn encode_chunk_align(&self) -> usize {
        super::bits::byte_align_fields(self.width)
    }

    /// Zero-alloc decode into a caller-owned buffer (identical values to
    /// `decode`; block-kernel fused loop).
    fn decode_into(&self, msg: &Message, reference: &[f64], out: &mut [f64]) {
        let d = self.lattice.dim();
        assert_eq!(reference.len(), d);
        assert_eq!(out.len(), d);
        self.decode_fold(msg, reference, 0, d, |idx, v| out[idx] = v);
    }

    /// Fused streaming-fold kernel: one pass bitstream → accumulator,
    /// never materializing the decoded vector.
    fn decode_accumulate_into(&self, msg: &Message, reference: &[f64], weight: f64, acc: &mut [f64]) {
        let d = self.lattice.dim();
        assert_eq!(reference.len(), d);
        assert_eq!(acc.len(), d);
        self.decode_fold(msg, reference, 0, d, |idx, v| acc[idx] += weight * v);
    }

    /// Chunk-sharded fold kernel: seeks straight to coordinate `lo`'s bit
    /// offset (fixed-width stream ⇒ random access) and folds only
    /// `lo..lo + acc.len()`.
    fn decode_accumulate_range(
        &self,
        msg: &Message,
        reference: &[f64],
        weight: f64,
        lo: usize,
        acc: &mut [f64],
    ) {
        let d = self.lattice.dim();
        assert_eq!(reference.len(), d);
        assert!(lo + acc.len() <= d);
        self.decode_fold(msg, reference, lo, acc.len(), |idx, v| {
            acc[idx - lo] += weight * v
        });
    }

    fn needs_reference(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dist_inf;

    #[test]
    fn exact_bit_count() {
        let mut rng = Rng::new(1);
        let codec = LatticeQuantizer::from_y(100, 8, 1.0, &mut rng);
        assert_eq!(codec.message_bits(), 300);
        let codec = LatticeQuantizer::from_y(100, 16, 1.0, &mut rng);
        assert_eq!(codec.message_bits(), 400);
        // Non-power-of-two q: ceil(log2 5) = 3 bits.
        let codec = LatticeQuantizer::from_y(100, 5, 1.0, &mut rng);
        assert_eq!(codec.message_bits(), 300);
    }

    #[test]
    fn decode_exact_within_y() {
        let mut shared = Rng::new(7);
        let mut rng = Rng::new(8);
        let d = 100;
        let q = 8;
        let y = 0.5;
        for _ in 0..20 {
            let mut codec = LatticeQuantizer::from_y(d, q, y, &mut shared);
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-100.0, 100.0)).collect();
            let xv: Vec<f64> = x.iter().map(|xi| xi + rng.uniform(-y, y)).collect();
            assert!(dist_inf(&x, &xv) <= y);
            let (msg, point) = codec.encode_with_point(&x);
            let z = codec.decode(&msg, &xv);
            for (zi, pi) in z.iter().zip(&point) {
                assert!(
                    (zi - pi).abs() < 1e-9,
                    "decoded point must equal encoded lattice point"
                );
            }
            // Quantization error bounded by s/2 per coordinate.
            let s = codec.lattice.s;
            assert!(dist_inf(&z, &x) <= s / 2.0 + 1e-12);
        }
    }

    #[test]
    fn unbiased_over_shared_offsets() {
        // E[Q(x)] = x when the offset is uniform in [-s/2, s/2).
        let d = 4;
        let q = 8;
        let y = 1.0;
        let x = vec![0.3141, -2.718, 10.0, -0.001];
        let trials = 60_000;
        let mut shared = Rng::new(42);
        let mut acc = vec![0.0; d];
        let s = side_for_y(y, q);
        for _ in 0..trials {
            let codec = LatticeQuantizer::from_y(d, q, y, &mut shared);
            let (_, point) = codec.encode_with_point(&x);
            for (a, p) in acc.iter_mut().zip(&point) {
                *a += p;
            }
        }
        for (a, xi) in acc.iter().zip(&x) {
            let mean = a / trials as f64;
            // std of the mean ≈ (s/sqrt 12)/sqrt(trials)
            let tol = 5.0 * (s / 12f64.sqrt()) / (trials as f64).sqrt();
            assert!(
                (mean - xi).abs() < tol,
                "biased: mean {mean} vs {xi} (tol {tol})"
            );
        }
    }

    #[test]
    fn encode_into_and_decode_into_match_allocating_paths() {
        let mut shared = Rng::new(21);
        let mut rng = Rng::new(22);
        for q in [5u32, 8, 16, 255] {
            let d = 97;
            let mut codec = LatticeQuantizer::from_y(d, q, 1.0, &mut shared);
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-50.0, 50.0)).collect();
            let xv: Vec<f64> = x.iter().map(|v| v + rng.uniform(-0.9, 0.9)).collect();
            let fresh = codec.encode(&x, &mut rng);
            // Scratch starts with stale garbage from a previous round.
            let mut scratch = Message {
                bytes: vec![0xFF; 4],
                bits: 32,
            };
            codec.encode_into(&x, &mut rng, &mut scratch);
            assert_eq!(scratch, fresh, "encode_into must be bit-identical");
            let z = codec.decode(&fresh, &xv);
            let mut z2 = vec![0.0; d];
            codec.decode_into(&fresh, &xv, &mut z2);
            assert_eq!(z, z2, "decode_into must be value-identical");
        }
    }

    #[test]
    fn encode_range_chunks_stitch_into_the_sequential_stream() {
        let mut shared = Rng::new(41);
        let mut rng = Rng::new(42);
        for q in [8u32, 16, 255] {
            let d = 203;
            let mut codec = LatticeQuantizer::from_y(d, q, 1.0, &mut shared);
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-50.0, 50.0)).collect();
            let full = codec.encode(&x, &mut rng);
            // Split at a byte-aligned coordinate; the two range streams
            // must concatenate into the sequential message unchanged.
            let align = codec.encode_chunk_align();
            let lo = (d / 2).div_ceil(align) * align;
            let mut w = crate::quant::bits::BitWriter::new();
            codec.encode_range(&x, 0, lo, &mut w);
            let (mut bytes, head_bits) = w.finish();
            assert_eq!(head_bits % 8, 0, "interior chunk must end on a byte");
            let mut w = crate::quant::bits::BitWriter::new();
            codec.encode_range(&x, lo, d - lo, &mut w);
            let (tail, tail_bits) = w.finish();
            bytes.extend_from_slice(&tail);
            let stitched = Message {
                bytes,
                bits: head_bits + tail_bits,
            };
            assert_eq!(stitched, full, "q={q}");
        }
    }

    #[test]
    fn fused_fold_kernels_match_decode_plus_axpy() {
        let mut shared = Rng::new(31);
        let mut rng = Rng::new(32);
        for (d, q) in [(1usize, 8u32), (7, 5), (97, 8), (300, 16), (4096, 255)] {
            let mut codec = LatticeQuantizer::from_y(d, q, 1.0, &mut shared);
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-50.0, 50.0)).collect();
            let xv: Vec<f64> = x.iter().map(|v| v + rng.uniform(-0.9, 0.9)).collect();
            let msg = codec.encode(&x, &mut rng);
            let z = codec.decode(&msg, &xv);
            let w = rng.uniform(-2.0, 2.0);
            // Stale accumulator, arbitrary weight.
            let stale: Vec<f64> = (0..d).map(|_| rng.uniform(-5.0, 5.0)).collect();
            let mut expect = stale.clone();
            crate::linalg::axpy(&mut expect, w, &z);
            let mut acc = stale.clone();
            codec.decode_accumulate_into(&msg, &xv, w, &mut acc);
            assert_eq!(acc, expect, "fused fold must be bit-identical (d={d} q={q})");
            // Range kernel over an interior chunk reproduces the slice.
            if d >= 8 {
                let lo = d / 3;
                let hi = d - d / 4;
                let mut acc_r = stale[lo..hi].to_vec();
                codec.decode_accumulate_range(&msg, &xv, w, lo, &mut acc_r);
                assert_eq!(acc_r, expect[lo..hi], "range fold chunk (d={d} q={q})");
            }
        }
    }

    #[test]
    fn decode_fails_gracefully_far_outside_radius() {
        // Outside the success radius the decoder returns *some* same-color
        // point near its reference — distance to the true point is then
        // at least q*s in the offending coordinate.
        let mut shared = Rng::new(3);
        let q = 8;
        let mut codec = LatticeQuantizer::from_y(4, q, 0.1, &mut shared);
        let x = vec![0.0; 4];
        let far = vec![1000.0; 4];
        let mut rng = Rng::new(4);
        let msg = codec.encode(&x, &mut rng);
        let z = codec.decode(&msg, &far);
        // Decoded near the (wrong) reference, not near x.
        assert!(dist_inf(&z, &far) <= q as f64 * codec.lattice.s);
    }

    #[test]
    fn variance_matches_uniform_model() {
        // With random offset, per-coordinate error is U[-s/2, s/2):
        // E[err²] = s²/12 (the model the paper uses in Exp 4).
        let d = 512;
        let q = 8;
        let y = 1.0;
        let s = side_for_y(y, q);
        let mut shared = Rng::new(17);
        let x: Vec<f64> = (0..d).map(|i| (i as f64) * 0.0137).collect();
        let mut total = 0.0;
        let trials = 2000;
        for _ in 0..trials {
            let codec = LatticeQuantizer::from_y(d, q, y, &mut shared);
            let (_, p) = codec.encode_with_point(&x);
            total += x
                .iter()
                .zip(&p)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>();
        }
        let measured = total / (trials as f64 * d as f64);
        let model = s * s / 12.0;
        assert!(
            (measured / model - 1.0).abs() < 0.05,
            "measured {measured} vs model {model}"
        );
    }
}
