//! Bit-level packing for wire messages.
//!
//! The paper's cost measure is exact bits (a color is `log₂ q` bits, not a
//! byte), so messages are bit-packed: `BitWriter`/`BitReader` stream
//! fixed-width fields LSB-first into a byte buffer.

/// Width in bits needed to represent values `0..n` (n ≥ 1).
#[inline]
pub fn width_for(n: u64) -> u32 {
    if n <= 1 {
        0
    } else {
        64 - (n - 1).leading_zeros()
    }
}

/// Smallest count of `width`-bit fields whose total length is a whole
/// number of bytes: `8/gcd(width, 8)`. This is the chunk quantum of the
/// parallel encode ([`crate::quant::encode_chunked`]) — chunks of a
/// multiple of this many fields start at byte boundaries, so per-chunk
/// writers concatenate bit-identically to one sequential stream.
pub fn byte_align_fields(width: u32) -> usize {
    if width == 0 {
        return 1;
    }
    // gcd(width, 8) = 2^min(trailing_zeros(width), 3).
    (8 >> width.trailing_zeros().min(3)) as usize
}

/// LSB-first bit writer with a 64-bit accumulator (full words are flushed
/// in one `to_le_bytes` store — the hot path of every lattice encode).
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    acc: u64,
    acc_bits: u32,
    /// Bits already written (including those still in the accumulator).
    len: u64,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bits: usize) -> Self {
        BitWriter {
            buf: Vec::with_capacity(bits / 8 + 9),
            acc: 0,
            acc_bits: 0,
            len: 0,
        }
    }

    /// Write into a recycled buffer (cleared, capacity kept) — the
    /// zero-realloc path of [`crate::quant::VectorCodec::encode_into`]:
    /// after the first round a session's scratch message never grows.
    pub fn reusing(mut buf: Vec<u8>) -> Self {
        buf.clear();
        BitWriter {
            buf,
            acc: 0,
            acc_bits: 0,
            len: 0,
        }
    }

    /// Append the low `width` bits of `v`.
    #[inline]
    pub fn push(&mut self, v: u64, width: u32) {
        debug_assert!(width <= 64);
        debug_assert!(width == 64 || v < (1u64 << width));
        if width == 0 {
            return;
        }
        self.len += width as u64;
        self.acc |= v << self.acc_bits;
        let total = self.acc_bits + width;
        if total >= 64 {
            self.buf.extend_from_slice(&self.acc.to_le_bytes());
            let consumed = 64 - self.acc_bits;
            if consumed >= width {
                self.acc = 0;
                self.acc_bits = 0;
            } else {
                self.acc = v >> consumed;
                self.acc_bits = width - consumed;
            }
        } else {
            self.acc_bits = total;
        }
    }

    /// Append `vals.len()` consecutive fixed-width fields in one call —
    /// the word-granular write kernel under every lattice encode loop,
    /// the write-side twin of [`BitReader::read_block`].
    ///
    /// Instead of one overflow check per field ([`Self::push`]), each
    /// accumulator store absorbs all the `⌊(64 − filled)/width⌋` fields
    /// that fully fit before it, so narrow widths (3–8 bits, every
    /// experiment config) amortize one store over 8–21 colors. The bit
    /// stream is identical to `width`-bit `push` calls in sequence;
    /// straddling fields fall through to a split store.
    pub fn push_block(&mut self, vals: &[u64], width: u32) {
        debug_assert!(width <= 64);
        if width == 0 {
            return;
        }
        self.len += vals.len() as u64 * width as u64;
        let n = vals.len();
        let mut i = 0;
        while i < n {
            let room = 64 - self.acc_bits;
            if room >= width {
                // Pack every field that fully fits before the next store,
                // OR-folded in lanes ([`crate::simd::pack_fields`] —
                // shift/or only, so the word is identical to the scalar
                // fold regardless of dispatch).
                let fit = ((room / width) as usize).min(n - i);
                debug_assert!(
                    width == 64 || vals[i..i + fit].iter().all(|&v| v < (1u64 << width))
                );
                let acc =
                    self.acc | crate::simd::pack_fields(&vals[i..i + fit], width, self.acc_bits);
                let bits = self.acc_bits + fit as u32 * width;
                self.acc = acc;
                self.acc_bits = bits;
                i += fit;
                if bits == 64 {
                    self.buf.extend_from_slice(&acc.to_le_bytes());
                    self.acc = 0;
                    self.acc_bits = 0;
                }
            } else {
                // Straddling field: its low `room` bits complete the
                // current word, the high bits seed the next accumulator.
                let v = vals[i];
                debug_assert!(width == 64 || v < (1u64 << width));
                let acc = self.acc | (v << self.acc_bits);
                self.buf.extend_from_slice(&acc.to_le_bytes());
                self.acc = v >> room;
                self.acc_bits = width - room;
                i += 1;
            }
        }
    }

    /// Append a full f64 (64 bits).
    pub fn push_f64(&mut self, v: f64) {
        self.push(v.to_bits(), 64);
    }

    /// Append an f32 (32 bits).
    pub fn push_f32(&mut self, v: f32) {
        self.push(v.to_bits() as u64, 32);
    }

    pub fn bit_len(&self) -> u64 {
        self.len
    }

    pub fn finish(mut self) -> (Vec<u8>, u64) {
        // Flush the accumulator's remaining bytes (trim to ⌈len/8⌉).
        if self.acc_bits > 0 {
            let bytes = (self.acc_bits as usize + 7) / 8;
            self.buf
                .extend_from_slice(&self.acc.to_le_bytes()[..bytes]);
        }
        debug_assert_eq!(self.buf.len(), (self.len as usize + 7) / 8);
        (self.buf, self.len)
    }
}

/// LSB-first bit reader.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: u64,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0 }
    }

    /// Read `width` bits (panics past end — messages are length-checked by
    /// construction in this codebase).
    #[inline]
    pub fn read(&mut self, width: u32) -> u64 {
        debug_assert!(width <= 64);
        if width == 0 {
            return 0;
        }
        let byte = (self.pos / 8) as usize;
        let shift = (self.pos % 8) as u32;
        // Fast path: one unaligned word load covers the field.
        if width + shift <= 64 && byte + 8 <= self.buf.len() {
            let w = u64::from_le_bytes(self.buf[byte..byte + 8].try_into().unwrap());
            self.pos += width as u64;
            let v = w >> shift;
            return if width == 64 { v } else { v & ((1u64 << width) - 1) };
        }
        // Slow path (tail of the buffer / wide straddling fields).
        let mut v = 0u64;
        let mut got = 0u32;
        while got < width {
            let byte = self.buf[(self.pos / 8) as usize];
            let bit_in_byte = (self.pos % 8) as u32;
            let avail = 8 - bit_in_byte;
            let take = (width - got).min(avail);
            let chunk = ((byte >> bit_in_byte) as u64) & ((1u64 << take) - 1);
            v |= chunk << got;
            got += take;
            self.pos += take as u64;
        }
        v
    }

    /// Decode `out.len()` consecutive fixed-width fields in one call — the
    /// word-granular block kernel under every lattice decode loop.
    ///
    /// Instead of one unaligned word load per field ([`Self::read`]), each
    /// load yields all the `⌊(64 − shift)/width⌋` fields it fully covers,
    /// so narrow widths (3–8 bits, every experiment config) amortize one
    /// load over 8–21 colors. Values are identical to `width`-bit `read`
    /// calls in sequence; straddling fields and the buffer tail fall back
    /// to the scalar path.
    pub fn read_block(&mut self, width: u32, out: &mut [u64]) {
        debug_assert!(width <= 64);
        if width == 0 {
            out.fill(0);
            return;
        }
        let mask = if width == 64 {
            u64::MAX
        } else {
            (1u64 << width) - 1
        };
        let mut i = 0;
        while i < out.len() {
            let byte = (self.pos / 8) as usize;
            if byte + 8 > self.buf.len() {
                break; // tail: scalar reads below
            }
            let shift = (self.pos % 8) as u32;
            let avail = 64 - shift;
            if avail < width {
                // Field straddles the loaded word; read() handles it.
                out[i] = self.read(width);
                i += 1;
                continue;
            }
            let w = u64::from_le_bytes(self.buf[byte..byte + 8].try_into().unwrap()) >> shift;
            let fit = ((avail / width) as usize).min(out.len() - i);
            // Field extraction in lanes ([`crate::simd::unpack_fields`] —
            // shift/mask only, value-identical to the scalar loop).
            crate::simd::unpack_fields(w, width, mask, &mut out[i..i + fit]);
            self.pos += fit as u64 * width as u64;
            i += fit;
        }
        for o in out[i..].iter_mut() {
            *o = self.read(width);
        }
    }

    /// Reposition to an absolute bit offset. Fixed-width streams are
    /// random-access, which is what lets the chunk-sharded fold kernels
    /// ([`crate::quant::VectorCodec::decode_accumulate_range`]) start
    /// mid-message.
    pub fn seek(&mut self, bit: u64) {
        self.pos = bit;
    }

    pub fn read_f64(&mut self) -> f64 {
        f64::from_bits(self.read(64))
    }

    pub fn read_f32(&mut self) -> f32 {
        f32::from_bits(self.read(32) as u32)
    }

    pub fn bits_consumed(&self) -> u64 {
        self.pos
    }
}

/// Pack a slice of small unsigned values at a fixed width.
pub fn pack(values: &[u64], width: u32) -> (Vec<u8>, u64) {
    let mut w = BitWriter::with_capacity(values.len() * width as usize);
    for &v in values {
        w.push(v, width);
    }
    w.finish()
}

/// Unpack `count` fixed-width values.
pub fn unpack(bytes: &[u8], width: u32, count: usize) -> Vec<u64> {
    let mut r = BitReader::new(bytes);
    (0..count).map(|_| r.read(width)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn width_for_basics() {
        assert_eq!(width_for(1), 0);
        assert_eq!(width_for(2), 1);
        assert_eq!(width_for(8), 3);
        assert_eq!(width_for(9), 4);
        assert_eq!(width_for(1 << 20), 20);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let mut rng = Rng::new(5);
        for width in [1u32, 3, 5, 7, 8, 11, 16, 31] {
            let n = 257;
            let vals: Vec<u64> = (0..n)
                .map(|_| rng.next_u64() & ((1u64 << width) - 1))
                .collect();
            let (bytes, bits) = pack(&vals, width);
            assert_eq!(bits, n as u64 * width as u64);
            assert_eq!(bytes.len(), (bits as usize + 7) / 8);
            assert_eq!(unpack(&bytes, width, n), vals);
        }
    }

    #[test]
    fn mixed_fields() {
        let mut w = BitWriter::new();
        w.push(5, 3);
        w.push_f64(3.5);
        w.push(1023, 10);
        w.push_f32(-2.25);
        let (bytes, bits) = w.finish();
        assert_eq!(bits, 3 + 64 + 10 + 32);
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(3), 5);
        assert_eq!(r.read_f64(), 3.5);
        assert_eq!(r.read(10), 1023);
        assert_eq!(r.read_f32(), -2.25);
    }

    #[test]
    fn read_block_matches_scalar_reads_all_widths() {
        let mut rng = Rng::new(9);
        for width in 1..=64u32 {
            let n = 131;
            let vals: Vec<u64> = (0..n)
                .map(|_| {
                    let m = if width == 64 {
                        u64::MAX
                    } else {
                        (1u64 << width) - 1
                    };
                    rng.next_u64() & m
                })
                .collect();
            let (bytes, _) = pack(&vals, width);
            let mut block = vec![0u64; n];
            let mut r = BitReader::new(&bytes);
            r.read_block(width, &mut block);
            assert_eq!(block, vals, "width {width}");
        }
    }

    #[test]
    fn read_block_from_unaligned_start() {
        // A 5-bit prefix misaligns every subsequent word load.
        let mut w = BitWriter::new();
        w.push(0b10110, 5);
        let vals: Vec<u64> = (0..97).map(|i| (i * 37) % 128).collect();
        for &v in &vals {
            w.push(v, 7);
        }
        let (bytes, _) = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(5), 0b10110);
        let mut block = vec![0u64; vals.len()];
        r.read_block(7, &mut block);
        assert_eq!(block, vals);
    }

    #[test]
    fn seek_gives_random_access_into_fixed_width_stream() {
        let vals: Vec<u64> = (0..200).map(|i| (i * 11) % 32).collect();
        let (bytes, _) = pack(&vals, 5);
        let mut r = BitReader::new(&bytes);
        r.seek(5 * 137);
        assert_eq!(r.read(5), vals[137]);
        r.seek(0);
        let mut block = vec![0u64; 3];
        r.read_block(5, &mut block);
        assert_eq!(block, &vals[..3]);
    }

    #[test]
    fn byte_align_fields_totals_whole_bytes() {
        assert_eq!(byte_align_fields(0), 1);
        for width in 1..=64u32 {
            let n = byte_align_fields(width);
            assert_eq!((n as u32 * width) % 8, 0, "width {width}");
            // Minimality: no smaller count lands on a byte boundary.
            for m in 1..n {
                assert_ne!((m as u32 * width) % 8, 0, "width {width} m {m}");
            }
        }
    }

    #[test]
    fn push_block_matches_scalar_pushes_all_widths() {
        let mut rng = Rng::new(23);
        for width in 1..=64u32 {
            let n = 131;
            let m = if width == 64 {
                u64::MAX
            } else {
                (1u64 << width) - 1
            };
            let vals: Vec<u64> = (0..n).map(|_| rng.next_u64() & m).collect();
            let mut scalar = BitWriter::new();
            for &v in &vals {
                scalar.push(v, width);
            }
            let mut block = BitWriter::new();
            block.push_block(&vals, width);
            assert_eq!(block.bit_len(), scalar.bit_len(), "width {width}");
            assert_eq!(block.finish(), scalar.finish(), "width {width}");
        }
    }

    #[test]
    fn push_block_from_unaligned_start() {
        // A 5-bit prefix misaligns every subsequent accumulator store, so
        // the straddle path runs on every word boundary.
        let vals: Vec<u64> = (0..97).map(|i| (i * 37) % 128).collect();
        let mut scalar = BitWriter::new();
        scalar.push(0b10110, 5);
        for &v in &vals {
            scalar.push(v, 7);
        }
        let mut block = BitWriter::new();
        block.push(0b10110, 5);
        block.push_block(&vals, 7);
        assert_eq!(block.finish(), scalar.finish());
    }

    #[test]
    fn push_block_zero_width_is_a_noop() {
        let mut w = BitWriter::new();
        w.push(3, 2);
        w.push_block(&[9, 9, 9], 0);
        let (bytes, bits) = w.finish();
        assert_eq!(bits, 2);
        assert_eq!(bytes, vec![3u8]);
    }

    #[test]
    fn push_block_roundtrips_through_read_block() {
        let mut rng = Rng::new(29);
        for width in [1u32, 3, 5, 7, 8, 11, 13, 31, 33, 64] {
            let n = 257;
            let m = if width == 64 {
                u64::MAX
            } else {
                (1u64 << width) - 1
            };
            let vals: Vec<u64> = (0..n).map(|_| rng.next_u64() & m).collect();
            let mut w = BitWriter::new();
            w.push_block(&vals, width);
            let (bytes, _) = w.finish();
            let mut out = vec![0u64; n];
            BitReader::new(&bytes).read_block(width, &mut out);
            assert_eq!(out, vals, "width {width}");
        }
    }

    #[test]
    fn read_block_zero_width() {
        let (bytes, _) = pack(&[1, 2, 3], 2);
        let mut r = BitReader::new(&bytes);
        let mut block = vec![7u64; 4];
        r.read_block(0, &mut block);
        assert_eq!(block, vec![0, 0, 0, 0]);
        assert_eq!(r.bits_consumed(), 0);
    }

    #[test]
    fn zero_width_reads_zero() {
        let (bytes, bits) = pack(&[0, 0, 0], 0);
        assert_eq!(bits, 0);
        assert_eq!(unpack(&bytes, 0, 3), vec![0, 0, 0]);
    }
}
