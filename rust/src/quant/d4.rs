//! D4 lattice quantizer — the paper's §6 future-work item ("find specific
//! lattices which admit more efficient algorithms, and also have a good
//! r_c/r_p ratio under ℓ1 or ℓ2 norm") made concrete.
//!
//! Coordinates are processed in buckets of 4 (the bucketing §6 notes is
//! already standard in NN training) on the checkerboard lattice
//! `D4 = {k ∈ ℤ⁴ : Σk_i even}` — the densest lattice packing in 4
//! dimensions. Relative to the cubic lattice at the same scale:
//!
//! * **1 bit saved per bucket**: with even `q`, every color vector
//!   `c = k mod q` of a D4 point has even coordinate sum, so the last
//!   color's lowest bit is implied by the other three and is never
//!   transmitted (`4·log₂q − 1` bits per bucket).
//! * **same decode geometry**: distinct same-color points still differ by
//!   `q·m`, so proximity decoding succeeds under the usual radius, and
//!   the coordinate-wise nearest same-color point is automatically in D4.
//! * **~0.4 dB rate–distortion gain** (D4's normalized second moment
//!   0.0766 vs the cube's 1/12) — measured by the ablation test below.
//!
//! Unbiasedness uses *subtractive dither*: the shared offset is drawn
//! uniformly from the D4 **Voronoi cell** (the 24-cell) by rejection
//! sampling, making the quantization error uniform over the cell and
//! zero-mean — the exact analogue of §9.1's cube-uniform offset.

use super::bits::{width_for, BitReader, BitWriter};
use super::{Message, VectorCodec};
use crate::rng::Rng;

/// Nearest D4 point to `t` (Conway–Sloane): round coordinate-wise; if the
/// parity is odd, re-round the coordinate whose fractional part is
/// farthest from its integer toward the other side.
pub fn nearest_d4(t: &[f64; 4]) -> [i64; 4] {
    let mut k = [0i64; 4];
    let mut sum = 0i64;
    for i in 0..4 {
        k[i] = t[i].round_ties_even() as i64;
        sum += k[i];
    }
    if sum.rem_euclid(2) != 0 {
        // Flip the worst-rounded coordinate.
        let mut worst = 0;
        let mut worst_err = -1.0;
        for i in 0..4 {
            let err = (t[i] - k[i] as f64).abs();
            if err > worst_err {
                worst_err = err;
                worst = i;
            }
        }
        let d = t[worst] - k[worst] as f64;
        k[worst] += if d > 0.0 {
            1
        } else if d < 0.0 {
            -1
        } else {
            1 // exact integer: either neighbour restores parity
        };
    }
    k
}

/// Draw a point uniform over the D4 Voronoi cell (24-cell) of the origin,
/// by rejection from the enclosing cube `[-1, 1]⁴` (acceptance = 1/8).
pub fn voronoi_dither_d4(rng: &mut Rng) -> [f64; 4] {
    loop {
        let u = [
            rng.uniform(-1.0, 1.0),
            rng.uniform(-1.0, 1.0),
            rng.uniform(-1.0, 1.0),
            rng.uniform(-1.0, 1.0),
        ];
        if nearest_d4(&u) == [0, 0, 0, 0] {
            return u;
        }
    }
}

/// D4 bucketed lattice quantizer (d must be a multiple of 4; `q` even
/// and a power of two).
#[derive(Clone, Debug)]
pub struct D4Quantizer {
    pub d: usize,
    pub q: u32,
    pub s: f64,
    /// Per-coordinate dither, Voronoi-uniform per 4-bucket, scaled by s.
    pub offset: Vec<f64>,
    width: u32,
}

impl D4Quantizer {
    pub fn new(d: usize, q: u32, s: f64, shared: &mut Rng) -> Self {
        assert!(d % 4 == 0, "D4 buckets need d % 4 == 0");
        assert!(q >= 4 && q.is_power_of_two(), "q must be an even power of two");
        assert!(s > 0.0);
        let mut offset = Vec::with_capacity(d);
        for _ in 0..d / 4 {
            let th = voronoi_dither_d4(shared);
            offset.extend(th.iter().map(|v| v * s));
        }
        D4Quantizer {
            d,
            q,
            s,
            offset,
            width: width_for(q as u64),
        }
    }

    /// Paper-style parameterization from an ℓ∞ distance bound `y`:
    /// the D4 rounding can move one coordinate up to `s` (vs `s/2`
    /// cubic), so the success condition tightens to `(q−2)·s/2 ≥ y + s`.
    pub fn from_y(d: usize, q: u32, y: f64, shared: &mut Rng) -> Self {
        let s = 2.0 * y.max(f64::MIN_POSITIVE) / (q as f64 - 4.0).max(1.0);
        Self::new(d, q, s, shared)
    }

    /// Exact message size: `(4·⌈log₂q⌉ − 1) · d/4` bits.
    pub fn message_bits(&self) -> u64 {
        (4 * self.width as u64 - 1) * (self.d as u64 / 4)
    }

    /// Reconstruct the lattice point for bucket indices.
    pub fn point(&self, ks: &[[i64; 4]]) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.d);
        for (b, k) in ks.iter().enumerate() {
            for i in 0..4 {
                out.push(self.offset[4 * b + i] + self.s * k[i] as f64);
            }
        }
        out
    }

    /// The shared fused decode loop over buckets `bucket_lo..bucket_lo +
    /// buckets`: seeks to the bucket's bit offset, splits each packed
    /// bucket into its colors (reconstructing the parity-implied fourth
    /// LSB), and hands every coordinate to `emit(index, value)`. All
    /// decode entry points share this loop, so they are value-identical
    /// by construction.
    ///
    /// A packed bucket is one fixed-width `4·width − 1`-bit field, so for
    /// `width ≤ 16` (q ≤ 65536, every experiment config) whole buckets
    /// stream through the word-granular block kernel
    /// [`BitReader::read_block`] — one unaligned load covers
    /// ⌊64/(4·width−1)⌋ buckets — and the colors are split out with
    /// shifts. Wider q falls back to per-field reads.
    fn decode_fold(
        &self,
        msg: &Message,
        reference: &[f64],
        bucket_lo: usize,
        buckets: usize,
        mut emit: impl FnMut(usize, f64),
    ) {
        let w = self.width;
        let bucket_bits = 4 * w as u64 - 1;
        let mut r = BitReader::new(&msg.bytes);
        r.seek(bucket_lo as u64 * bucket_bits);
        let inv_sq = 1.0 / (self.s * self.q as f64);
        let inv_q = 1.0 / self.q as f64;
        let qi = self.q as i64;
        let mut do_bucket = |b: usize, c0: u64, c1: u64, c2: u64, c3_hi: u64| {
            // Implied parity bit: sum of colors is even.
            let lsb = (c0 ^ c1 ^ c2) & 1;
            let c3 = (c3_hi << 1) | lsb;
            for (i, c) in [c0, c1, c2, c3].into_iter().enumerate() {
                let j = 4 * b + i;
                let m = ((reference[j] - self.offset[j]) * inv_sq - c as f64 * inv_q)
                    .round_ties_even() as i64;
                let k = c as i64 + qi * m;
                emit(j, self.offset[j] + self.s * k as f64);
            }
        };
        if bucket_bits <= 64 {
            const BLOCK: usize = 64;
            let mask = (1u64 << w) - 1;
            let mut packed = [0u64; BLOCK];
            let mut done = 0;
            while done < buckets {
                let take = (buckets - done).min(BLOCK);
                r.read_block(bucket_bits as u32, &mut packed[..take]);
                for (i, &pv) in packed[..take].iter().enumerate() {
                    // LSB-first field order matches the encoder's pushes.
                    do_bucket(
                        bucket_lo + done + i,
                        pv & mask,
                        (pv >> w) & mask,
                        (pv >> (2 * w)) & mask,
                        pv >> (3 * w),
                    );
                }
                done += take;
            }
        } else {
            for b in bucket_lo..bucket_lo + buckets {
                let c0 = r.read(w);
                let c1 = r.read(w);
                let c2 = r.read(w);
                let c3_hi = r.read(w - 1);
                do_bucket(b, c0, c1, c2, c3_hi);
            }
        }
    }

    /// The shared fused encode loop over buckets `bucket_lo..bucket_lo +
    /// buckets` — the write-side twin of [`Self::decode_fold`]: each
    /// bucket is quantized to its D4 index (reciprocal-folded, §Perf),
    /// masked to its colors (`q` is a power of two by construction, so
    /// there is never a per-coordinate branch), composed into one packed
    /// `4·width − 1`-bit field (three full colors + the fourth without
    /// its parity-implied LSB, LSB-first — exactly the field order the
    /// scalar pushes produced), and streamed through the word-granular
    /// write kernel [`BitWriter::push_block`]. Wider `q` (width > 16)
    /// falls back to per-field pushes, mirroring the decode fallback.
    /// Every encode entry point is this loop with a different `emit`
    /// sink, so they are bit-identical by construction.
    fn encode_fold(
        &self,
        x: &[f64],
        bucket_lo: usize,
        buckets: usize,
        w: &mut BitWriter,
        mut emit: impl FnMut(usize, i64),
    ) {
        let wd = self.width;
        let mask = (self.q - 1) as i64;
        let inv = 1.0 / self.s;
        let bucket_bits = 4 * wd - 1;
        if bucket_bits <= 64 {
            const BLOCK: usize = 64;
            let mut packed = [0u64; BLOCK];
            let mut tbuf = [0.0f64; 4 * BLOCK];
            let mut done = 0;
            while done < buckets {
                let take = (buckets - done).min(BLOCK);
                let base = 4 * (bucket_lo + done);
                // Vector stage (§Perf): all 4·take bucket coordinates are
                // offset-scaled in one pass through
                // [`crate::simd::scale_offset`]; `nearest_d4` and the
                // color/pack stage below consume those exact f64s, so the
                // staging changes no bit.
                crate::simd::scale_offset(
                    &x[base..base + 4 * take],
                    &self.offset[base..base + 4 * take],
                    inv,
                    &mut tbuf[..4 * take],
                );
                for (slot, p) in packed[..take].iter_mut().enumerate() {
                    let t: [f64; 4] = tbuf[4 * slot..4 * slot + 4].try_into().unwrap();
                    let k = nearest_d4(&t);
                    let mut c = [0u64; 4];
                    for (i, ci) in c.iter_mut().enumerate() {
                        *ci = (k[i] & mask) as u64;
                        emit(base + 4 * slot + i, k[i]);
                    }
                    debug_assert_eq!((c[0] + c[1] + c[2] + c[3]) % 2, 0);
                    *p = c[0] | (c[1] << wd) | (c[2] << (2 * wd)) | ((c[3] >> 1) << (3 * wd));
                }
                w.push_block(&packed[..take], bucket_bits);
                done += take;
            }
        } else {
            // Wide-q fallback: per-bucket scalar staging (mirrors the
            // decode fallback; the block path above never runs here).
            for b in bucket_lo..bucket_lo + buckets {
                let mut t = [0.0f64; 4];
                for (i, ti) in t.iter_mut().enumerate() {
                    let j = 4 * b + i;
                    *ti = (x[j] - self.offset[j]) * inv;
                }
                let k = nearest_d4(&t);
                let mut c = [0u64; 4];
                for (i, ci) in c.iter_mut().enumerate() {
                    *ci = (k[i] & mask) as u64;
                    emit(4 * b + i, k[i]);
                }
                debug_assert_eq!((c[0] + c[1] + c[2] + c[3]) % 2, 0);
                w.push(c[0], wd);
                w.push(c[1], wd);
                w.push(c[2], wd);
                w.push(c[3] >> 1, wd - 1);
            }
        }
    }

    /// Encode returning the quantized point as well (the block kernel
    /// [`Self::encode_fold`] with a point-reconstruction sink).
    pub fn encode_with_point(&self, x: &[f64]) -> (Message, Vec<f64>) {
        assert_eq!(x.len(), self.d);
        let mut w = BitWriter::with_capacity(self.message_bits() as usize);
        let mut point = vec![0.0; self.d];
        self.encode_fold(x, 0, self.d / 4, &mut w, |j, k| {
            point[j] = self.offset[j] + self.s * k as f64;
        });
        let (bytes, bits) = w.finish();
        (Message { bytes, bits }, point)
    }
}

impl VectorCodec for D4Quantizer {
    fn name(&self) -> String {
        format!("D4LQ(q={})", self.q)
    }

    fn dim(&self) -> usize {
        self.d
    }

    /// Same bucket block kernel as `encode_into`, minus the point sink
    /// the y-estimation paths pay for in [`Self::encode_with_point`].
    fn encode(&mut self, x: &[f64], _rng: &mut Rng) -> Message {
        assert_eq!(x.len(), self.d);
        let mut w = BitWriter::with_capacity(self.message_bits() as usize);
        self.encode_fold(x, 0, self.d / 4, &mut w, |_, _| {});
        let (bytes, bits) = w.finish();
        Message { bytes, bits }
    }

    /// Zero-alloc encode: the bucket block kernel [`Self::encode_fold`]
    /// minus the point reconstruction, writing into the recycled scratch
    /// (bit-identical to `encode`).
    fn encode_into(&mut self, x: &[f64], _rng: &mut Rng, out: &mut Message) {
        assert_eq!(x.len(), self.d);
        let mut w = BitWriter::reusing(std::mem::take(&mut out.bytes));
        self.encode_fold(x, 0, self.d / 4, &mut w, |_, _| {});
        let (bytes, bits) = w.finish();
        out.bytes = bytes;
        out.bits = bits;
    }

    fn decode(&self, msg: &Message, reference: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.d];
        self.decode_into(msg, reference, &mut out);
        out
    }

    fn decode_into(&self, msg: &Message, reference: &[f64], out: &mut [f64]) {
        assert_eq!(reference.len(), self.d);
        assert_eq!(out.len(), self.d);
        self.decode_fold(msg, reference, 0, self.d / 4, |j, v| out[j] = v);
    }

    /// Fused streaming-fold kernel (single pass, parity bit reconstructed
    /// inline, no decoded-vector materialization).
    fn decode_accumulate_into(&self, msg: &Message, reference: &[f64], weight: f64, acc: &mut [f64]) {
        assert_eq!(reference.len(), self.d);
        assert_eq!(acc.len(), self.d);
        self.decode_fold(msg, reference, 0, self.d / 4, |j, v| acc[j] += weight * v);
    }

    /// Chunk-sharded fold kernel. Chunks must respect the bucket format:
    /// `lo` and `acc.len()` are multiples of 4 (see
    /// [`VectorCodec::fold_chunk_align`]).
    fn decode_accumulate_range(
        &self,
        msg: &Message,
        reference: &[f64],
        weight: f64,
        lo: usize,
        acc: &mut [f64],
    ) {
        assert_eq!(reference.len(), self.d);
        assert!(lo % 4 == 0 && acc.len() % 4 == 0, "D4 chunks are bucket-aligned");
        assert!(lo + acc.len() <= self.d);
        self.decode_fold(msg, reference, lo / 4, acc.len() / 4, |j, v| {
            acc[j - lo] += weight * v
        });
    }

    fn fold_chunk_align(&self) -> usize {
        4
    }

    /// Chunk kernel for the parallel encode: `lo`/`len` must be
    /// bucket-aligned (multiples of 4), matching
    /// [`VectorCodec::fold_chunk_align`] on the decode side.
    fn encode_range(&self, x: &[f64], lo: usize, len: usize, w: &mut BitWriter) {
        assert_eq!(x.len(), self.d);
        assert!(lo % 4 == 0 && len % 4 == 0, "D4 chunks are bucket-aligned");
        assert!(lo + len <= self.d);
        self.encode_fold(x, lo / 4, len / 4, w, |_, _| {});
    }

    fn supports_encode_range(&self) -> bool {
        true
    }

    /// A packed bucket is `4·width − 1` bits — always odd — so byte
    /// alignment needs 8 buckets: 32 coordinates per chunk quantum (the
    /// encode-side refinement of the decode folds' bucket alignment).
    fn encode_chunk_align(&self) -> usize {
        8 * 4
    }

    fn needs_reference(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dist_inf;

    #[test]
    fn nearest_d4_always_even_and_optimal() {
        let mut rng = Rng::new(1);
        for _ in 0..2000 {
            let t = [
                rng.uniform(-5.0, 5.0),
                rng.uniform(-5.0, 5.0),
                rng.uniform(-5.0, 5.0),
                rng.uniform(-5.0, 5.0),
            ];
            let k = nearest_d4(&t);
            assert_eq!(k.iter().sum::<i64>().rem_euclid(2), 0);
            // Optimality: no D4 point within the ±1 box around round(t)
            // is closer (exhaustive over the 3^4 neighbourhood).
            let d2 = |k: &[i64; 4]| -> f64 {
                k.iter()
                    .zip(&t)
                    .map(|(&ki, ti)| (ti - ki as f64).powi(2))
                    .sum()
            };
            let best = d2(&k);
            let base: Vec<i64> = t.iter().map(|v| v.round_ties_even() as i64).collect();
            for a in -1..=1i64 {
                for b in -1..=1i64 {
                    for c in -1..=1i64 {
                        for e in -1..=1i64 {
                            let cand = [base[0] + a, base[1] + b, base[2] + c, base[3] + e];
                            if cand.iter().sum::<i64>().rem_euclid(2) == 0 {
                                assert!(
                                    d2(&cand) >= best - 1e-12,
                                    "{cand:?} beats {k:?} for {t:?}"
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn dither_stays_in_voronoi_cell() {
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let u = voronoi_dither_d4(&mut rng);
            assert_eq!(nearest_d4(&u), [0, 0, 0, 0]);
        }
    }

    #[test]
    fn bit_saving_one_per_bucket() {
        let mut shared = Rng::new(3);
        let c = D4Quantizer::new(128, 16, 0.3, &mut shared);
        assert_eq!(c.message_bits(), (4 * 4 - 1) * 32); // 480 vs cubic 512
        let mut c = c;
        let msg = c.encode(&vec![1.0; 128], &mut Rng::new(0));
        assert_eq!(msg.bits, 480);
    }

    #[test]
    fn roundtrip_exact_within_radius() {
        let mut shared = Rng::new(4);
        let mut rng = Rng::new(5);
        let d = 64;
        let q = 16;
        for _ in 0..40 {
            let y = rng.uniform(0.1, 3.0);
            let mut codec = D4Quantizer::from_y(d, q, y, &mut shared);
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-50.0, 50.0)).collect();
            let xv: Vec<f64> = x.iter().map(|v| v + rng.uniform(-y, y)).collect();
            let (msg, point) = codec.encode_with_point(&x);
            let z = codec.decode(&msg, &xv);
            for (zi, pi) in z.iter().zip(&point) {
                assert!((zi - pi).abs() < 1e-9, "decode != encoded point");
            }
            let _ = codec.encode(&x, &mut rng);
        }
    }

    #[test]
    fn encode_into_and_range_match_allocating_encode() {
        let mut shared = Rng::new(11);
        let mut rng = Rng::new(12);
        for d in [4usize, 64, 260] {
            let mut codec = D4Quantizer::from_y(d, 16, 1.0, &mut shared);
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-20.0, 20.0)).collect();
            let fresh = codec.encode(&x, &mut rng);
            // Scratch starts with stale garbage from a previous round.
            let mut scratch = Message {
                bytes: vec![0xFF; 4],
                bits: 32,
            };
            codec.encode_into(&x, &mut rng, &mut scratch);
            assert_eq!(scratch, fresh, "encode_into must be bit-identical (d={d})");
            // The range kernel over the full span reproduces the stream.
            let mut w = BitWriter::new();
            codec.encode_range(&x, 0, d, &mut w);
            assert_eq!(w.finish(), (fresh.bytes, fresh.bits));
            assert!(codec.supports_encode_range());
            assert_eq!(codec.encode_chunk_align(), 32);
        }
    }

    #[test]
    fn fused_fold_kernels_match_decode_plus_axpy() {
        let mut shared = Rng::new(9);
        let mut rng = Rng::new(10);
        for (d, q) in [(4usize, 8u32), (64, 16), (256, 8)] {
            let mut codec = D4Quantizer::from_y(d, q, 1.0, &mut shared);
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-20.0, 20.0)).collect();
            let xv: Vec<f64> = x.iter().map(|v| v + rng.uniform(-0.5, 0.5)).collect();
            let msg = codec.encode(&x, &mut rng);
            let z = codec.decode(&msg, &xv);
            let mut z2 = vec![0.0; d];
            codec.decode_into(&msg, &xv, &mut z2);
            assert_eq!(z, z2, "decode_into parity");
            let w = rng.uniform(-2.0, 2.0);
            let stale: Vec<f64> = (0..d).map(|_| rng.uniform(-3.0, 3.0)).collect();
            let mut expect = stale.clone();
            crate::linalg::axpy(&mut expect, w, &z);
            let mut acc = stale.clone();
            codec.decode_accumulate_into(&msg, &xv, w, &mut acc);
            assert_eq!(acc, expect, "fused fold (d={d} q={q})");
            if d >= 16 {
                let lo = 4 * (d / 12); // bucket-aligned interior chunk
                let hi = d - 4;
                let mut acc_r = stale[lo..hi].to_vec();
                codec.decode_accumulate_range(&msg, &xv, w, lo, &mut acc_r);
                assert_eq!(acc_r, expect[lo..hi], "range fold (d={d} q={q})");
            }
            assert_eq!(codec.fold_chunk_align(), 4);
        }
    }

    #[test]
    fn unbiased_via_voronoi_dither() {
        let d = 4;
        let x = vec![0.37, -1.21, 5.05, 2.93];
        let trials = 40_000;
        let mut shared = Rng::new(6);
        let mut acc = vec![0.0; d];
        let s = 0.5;
        for _ in 0..trials {
            let c = D4Quantizer::new(d, 8, s, &mut shared);
            let (_, p) = c.encode_with_point(&x);
            for (a, pi) in acc.iter_mut().zip(&p) {
                *a += pi;
            }
        }
        for (a, xi) in acc.iter().zip(&x) {
            let mean = a / trials as f64;
            let tol = 6.0 * s / (trials as f64).sqrt();
            assert!((mean - xi).abs() < tol, "biased: {mean} vs {xi}");
        }
    }

    #[test]
    fn rate_distortion_beats_cubic() {
        // At matched scale, D4 spends 1 bit/bucket less; compare the
        // rate-distortion product MSE·4^{bits/d}: lower is better.
        let d = 256;
        let q = 16u32;
        let s = 0.4;
        let trials = 3000;
        let mut shared = Rng::new(7);
        let mut rng = Rng::new(8);
        let x: Vec<f64> = (0..d).map(|_| rng.uniform(-10.0, 10.0)).collect();

        let mut mse_d4 = 0.0;
        for _ in 0..trials {
            let c = D4Quantizer::new(d, q, s, &mut shared);
            let (_, p) = c.encode_with_point(&x);
            mse_d4 += x.iter().zip(&p).map(|(a, b)| (a - b).powi(2)).sum::<f64>();
        }
        mse_d4 /= (trials * d) as f64;
        let bits_d4 = (4.0 * 4.0 - 1.0) / 4.0; // 3.75 bits/coord

        let mut mse_cube = 0.0;
        for _ in 0..trials {
            let c = crate::quant::LatticeQuantizer::new(
                crate::quant::CubicLattice::random_offset(d, s, &mut shared),
                q,
            );
            let (_, p) = c.encode_with_point(&x);
            mse_cube += x.iter().zip(&p).map(|(a, b)| (a - b).powi(2)).sum::<f64>();
        }
        mse_cube /= (trials * d) as f64;
        let bits_cube = 4.0;

        let rd_d4 = mse_d4 * 4f64.powf(bits_d4);
        let rd_cube = mse_cube * 4f64.powf(bits_cube);
        assert!(
            rd_d4 < rd_cube,
            "D4 RD product {rd_d4:.4} must beat cubic {rd_cube:.4} \
             (mse d4 {mse_d4:.5} @ {bits_d4}b, cube {mse_cube:.5} @ {bits_cube}b)"
        );
    }
}
