//! Algorithm 1's theoretical unbiased rounding (convex-hull method),
//! specialized to the cubic lattice.
//!
//! For the cubic lattice the convex hull of the 2^d surrounding lattice
//! points factorizes coordinate-wise, so sampling a hull vertex with
//! hull-coefficient probabilities reduces to independent per-coordinate
//! stochastic rounding: round `t = (x−offset)/s` down with probability
//! `1−frac(t)`, up with probability `frac(t)`. This gives `E[z] = x`
//! *without* shared randomness (unlike the §9.1 random-offset variant),
//! at the cost of encoder-side randomness. Both variants are exposed so
//! the ablation bench can compare them (DESIGN.md E2 ablation).

use super::bits::{pack, unpack, width_for};
use super::lattice::{side_for_y, CubicLattice};
use super::{Message, VectorCodec};
use crate::rng::Rng;

/// LQSGD with encoder-side stochastic rounding (Algorithm 1) instead of a
/// shared random offset.
#[derive(Clone, Debug)]
pub struct ConvexHullEncoder {
    pub lattice: CubicLattice,
    pub q: u32,
    width: u32,
}

impl ConvexHullEncoder {
    pub fn new(lattice: CubicLattice, q: u32) -> Self {
        assert!(q >= 2);
        let width = width_for(q as u64);
        ConvexHullEncoder { lattice, q, width }
    }

    /// Paper parameterization from the distance bound `y`: note the
    /// stochastic rounding may move the encoded point up to `s` from `x`
    /// (vs `s/2` for nearest-point), so the success condition tightens to
    /// `‖x_u − x_v‖∞ ≤ (q−2)s/2`; we keep `s = 2y/(q−2)` accordingly.
    pub fn from_y(d: usize, q: u32, y: f64) -> Self {
        assert!(q >= 3);
        let s = side_for_y(y.max(f64::MIN_POSITIVE), q - 1); // 2y/(q-2)
        Self::new(CubicLattice::centered(d, s), q)
    }

    /// Stochastically round to a lattice index (unbiased).
    pub fn stochastic_index(&self, x: &[f64], rng: &mut Rng, out: &mut [i64]) {
        let inv = 1.0 / self.lattice.s;
        for ((o, xi), off) in out.iter_mut().zip(x).zip(&self.lattice.offset) {
            let t = (xi - off) * inv;
            let low = t.floor();
            let p_up = t - low;
            *o = low as i64 + if rng.next_f64() < p_up { 1 } else { 0 };
        }
    }

    pub fn message_bits(&self) -> u64 {
        self.lattice.dim() as u64 * self.width as u64
    }
}

impl VectorCodec for ConvexHullEncoder {
    fn name(&self) -> String {
        format!("LQ-hull(q={})", self.q)
    }

    fn dim(&self) -> usize {
        self.lattice.dim()
    }

    fn encode(&mut self, x: &[f64], rng: &mut Rng) -> Message {
        let d = self.lattice.dim();
        let mut k = vec![0i64; d];
        self.stochastic_index(x, rng, &mut k);
        let colors: Vec<u64> = k
            .iter()
            .map(|&ki| CubicLattice::color_of(ki, self.q) as u64)
            .collect();
        let (bytes, bits) = pack(&colors, self.width);
        Message { bytes, bits }
    }

    fn decode(&self, msg: &Message, reference: &[f64]) -> Vec<f64> {
        let d = self.lattice.dim();
        let colors64 = unpack(&msg.bytes, self.width, d);
        let colors: Vec<u32> = colors64.iter().map(|&c| c as u32).collect();
        let mut out = vec![0.0; d];
        self.lattice.decode(&colors, reference, self.q, &mut out);
        out
    }

    fn needs_reference(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stochastic_rounding_is_unbiased() {
        let enc = ConvexHullEncoder::from_y(1, 8, 1.0);
        let s = enc.lattice.s;
        let x = vec![0.37 * s];
        let mut rng = Rng::new(100);
        let trials = 200_000;
        let mut sum = 0.0;
        let mut k = vec![0i64];
        for _ in 0..trials {
            enc.stochastic_index(&x, &mut rng, &mut k);
            sum += k[0] as f64 * s;
        }
        let mean = sum / trials as f64;
        let tol = 5.0 * s / (trials as f64).sqrt();
        assert!((mean - x[0]).abs() < tol, "mean {mean} vs {}", x[0]);
    }

    #[test]
    fn rounds_to_adjacent_points_only() {
        let enc = ConvexHullEncoder::from_y(16, 8, 1.0);
        let mut rng = Rng::new(7);
        let x: Vec<f64> = (0..16).map(|_| rng.uniform(-4.0, 4.0)).collect();
        let mut k = vec![0i64; 16];
        for _ in 0..100 {
            enc.stochastic_index(&x, &mut rng, &mut k);
            for (ki, xi) in k.iter().zip(&x) {
                let t = xi / enc.lattice.s;
                assert!(
                    *ki == t.floor() as i64 || *ki == t.floor() as i64 + 1,
                    "rounded to non-adjacent point"
                );
            }
        }
    }

    #[test]
    fn roundtrip_within_tightened_radius() {
        let mut enc = ConvexHullEncoder::from_y(32, 8, 0.5);
        let mut rng = Rng::new(8);
        let x: Vec<f64> = (0..32).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let xv: Vec<f64> = x.iter().map(|v| v + rng.uniform(-0.5, 0.5)).collect();
        let msg = enc.encode(&x, &mut rng);
        let z = enc.decode(&msg, &xv);
        // Must decode to a point within s of x (the encoded point).
        assert!(crate::linalg::dist_inf(&z, &x) <= enc.lattice.s + 1e-12);
    }
}
