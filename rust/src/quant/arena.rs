//! Pooled wire-packet arena — the staging buffer of the batched round
//! control plane (§Perf).
//!
//! A batched round ([`crate::coordinator::DmeSession::round_batch`])
//! encodes all `B` of a machine's slots back-to-back through the fused
//! block kernels before any exchange happens. The packets land here: one
//! recycled `Vec<u8>` holding `B` length-prefixed packets, so the encode
//! phase of a whole batch performs zero steady-state allocation where
//! the sequential round loop staged (and for workers, cloned) a
//! [`Message`] per round.
//!
//! Framing: each packet is `[bits: u64 LE][len: u32 LE][len bytes]`. The
//! byte length is stored explicitly rather than derived from `bits` so
//! the framing works for any codec, including ones whose side floats
//! make `bytes.len()` exceed `ceil(bits / 8)`. Packets may end at any
//! bit/byte offset (misaligned tails are the common case for bit-packed
//! lattice streams); the prefix is what delimits them. Roundtrip and
//! reuse-across-batches behavior is pinned by `rust/tests/prop.rs`.

use super::Message;

const PREFIX: usize = 8 + 4; // bits (u64) + byte length (u32)

/// A recycled buffer of length-prefixed wire packets.
#[derive(Clone, Debug, Default)]
pub struct PacketArena {
    buf: Vec<u8>,
    packets: usize,
}

impl PacketArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Drop all packets, keeping the allocation for the next batch.
    pub fn clear(&mut self) {
        self.buf.clear();
        self.packets = 0;
    }

    /// Number of packets currently framed.
    pub fn len(&self) -> usize {
        self.packets
    }

    pub fn is_empty(&self) -> bool {
        self.packets == 0
    }

    /// Total staged bytes (frames included).
    pub fn byte_len(&self) -> usize {
        self.buf.len()
    }

    /// Append one packet (a message's wire bytes plus its exact metered
    /// bit count).
    pub fn push(&mut self, msg: &Message) {
        let len = u32::try_from(msg.bytes.len()).expect("packet under 4 GiB");
        self.buf.reserve(PREFIX + msg.bytes.len());
        self.buf.extend_from_slice(&msg.bits.to_le_bytes());
        self.buf.extend_from_slice(&len.to_le_bytes());
        self.buf.extend_from_slice(&msg.bytes);
        self.packets += 1;
    }

    /// The raw framed bytes — exactly the byte stream a TCP transport
    /// carries for the same packets ([`crate::net::frame`] reuses this
    /// format verbatim; the equivalence is pinned by
    /// `frame_bytes_match_packet_arena`).
    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }

    /// Sequential reader over the framed packets.
    pub fn reader(&self) -> PacketReader<'_> {
        PacketReader {
            buf: &self.buf,
            pos: 0,
            remaining: self.packets,
        }
    }
}

/// Borrowing cursor over a [`PacketArena`]'s packets, in push order.
pub struct PacketReader<'a> {
    buf: &'a [u8],
    pos: usize,
    remaining: usize,
}

impl<'a> PacketReader<'a> {
    /// Next packet as `(bits, bytes)`, or `None` past the last one.
    pub fn next_packet(&mut self) -> Option<(u64, &'a [u8])> {
        if self.remaining == 0 {
            return None;
        }
        let bits = u64::from_le_bytes(self.buf[self.pos..self.pos + 8].try_into().unwrap());
        let len =
            u32::from_le_bytes(self.buf[self.pos + 8..self.pos + 12].try_into().unwrap()) as usize;
        let start = self.pos + PREFIX;
        self.pos = start + len;
        self.remaining -= 1;
        Some((bits, &self.buf[start..start + len]))
    }

    /// Next packet materialized as an owned [`Message`] (the wire copy a
    /// send requires — the arena itself is never consumed).
    pub fn next_message(&mut self) -> Option<Message> {
        self.next_packet().map(|(bits, bytes)| Message {
            bytes: bytes.to_vec(),
            bits,
        })
    }

    /// Packets not yet read.
    pub fn remaining(&self) -> usize {
        self.remaining
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(bytes: Vec<u8>, bits: u64) -> Message {
        Message { bytes, bits }
    }

    #[test]
    fn roundtrip_preserves_bytes_and_bits() {
        let mut a = PacketArena::new();
        let msgs = [
            msg(vec![0xAB, 0xCD, 0xEF], 23), // misaligned tail
            msg(Vec::new(), 0),              // empty packet
            msg((0..67).collect(), 67 * 8),  // odd byte length
        ];
        for m in &msgs {
            a.push(m);
        }
        assert_eq!(a.len(), 3);
        let mut r = a.reader();
        for m in &msgs {
            let got = r.next_message().expect("packet present");
            assert_eq!(&got, m);
        }
        assert!(r.next_packet().is_none());
    }

    #[test]
    fn clear_recycles_capacity_across_batches() {
        let mut a = PacketArena::new();
        a.push(&msg(vec![1; 128], 1024));
        let cap = a.buf.capacity();
        a.clear();
        assert!(a.is_empty());
        assert_eq!(a.byte_len(), 0);
        assert_eq!(a.buf.capacity(), cap, "clear must keep the allocation");
        a.push(&msg(vec![2; 64], 511));
        let mut r = a.reader();
        let (bits, bytes) = r.next_packet().unwrap();
        assert_eq!(bits, 511);
        assert_eq!(bytes, &[2u8; 64][..]);
        assert_eq!(r.remaining(), 0);
    }
}
