//! The cubic lattice substrate (Definition 9, specialized per Section 6/9.1).
//!
//! A scaled cubic lattice with side `s` and per-coordinate offset
//! `offset[i]` consists of the points `offset + s·k, k ∈ ℤ^d`. Under ℓ∞ it
//! is an ε-lattice with `r_p = r_c = s/2` — optimal (Theorem 11). The
//! shared-randomness offset (uniform in `[-s/2, s/2)^d`) makes
//! nearest-point rounding unbiased (Section 9.1), replacing the
//! convex-hull rounding of Algorithm 1 (kept in [`super::convex_hull`]).
//!
//! Rounding is **round-half-to-even** to bit-match `jnp.round` in the
//! Pallas kernels, so Rust-native and AOT/HLO paths agree exactly.

use crate::rng::Rng;

/// A scaled, offset cubic lattice in `d` dimensions.
#[derive(Clone, Debug)]
pub struct CubicLattice {
    /// Side length (`s` in Section 9.1; `2ε` in the theory sections).
    pub s: f64,
    /// Per-coordinate offset, shared between encoder and decoder.
    pub offset: Vec<f64>,
    /// 1/s, precomputed at construction (§Perf): the per-coordinate
    /// divisions in the round/decode loops become multiplies.
    inv_s: f64,
}

impl CubicLattice {
    /// Lattice with a fixed offset.
    pub fn with_offset(s: f64, offset: Vec<f64>) -> Self {
        assert!(s > 0.0, "side length must be positive");
        CubicLattice {
            s,
            offset,
            inv_s: 1.0 / s,
        }
    }

    /// Lattice with the paper's shared-random offset: uniform in
    /// `[-s/2, s/2)` per coordinate, drawn from shared randomness.
    pub fn random_offset(d: usize, s: f64, shared: &mut Rng) -> Self {
        assert!(s > 0.0, "side length must be positive");
        let offset = (0..d).map(|_| shared.uniform(-s / 2.0, s / 2.0)).collect();
        Self::with_offset(s, offset)
    }

    /// Unshifted lattice (offset 0) — the theoretical sections' `Λ_ε`.
    pub fn centered(d: usize, s: f64) -> Self {
        Self::with_offset(s, vec![0.0; d])
    }

    pub fn dim(&self) -> usize {
        self.offset.len()
    }

    /// The precomputed reciprocal side length 1/s (§Perf: construction
    /// pays the division once; round loops multiply).
    #[inline]
    pub fn inv_s(&self) -> f64 {
        self.inv_s
    }

    /// Index of the nearest lattice point, coordinate-wise:
    /// `k_i = round((x_i - offset_i)/s)` with ties-to-even.
    #[inline]
    pub fn nearest_index(&self, x: &[f64], out: &mut [i64]) {
        debug_assert_eq!(x.len(), self.dim());
        let inv = self.inv_s;
        for ((o, xi), off) in out.iter_mut().zip(x).zip(&self.offset) {
            *o = ((xi - off) * inv).round_ties_even() as i64;
        }
    }

    /// Reconstruct the point for a lattice index.
    #[inline]
    pub fn point(&self, k: &[i64], out: &mut [f64]) {
        for ((o, ki), off) in out.iter_mut().zip(k).zip(&self.offset) {
            *o = off + self.s * *ki as f64;
        }
    }

    /// Color of an index under the mod-q coloring (Section 3.1):
    /// `c_i = k_i mod q ∈ [0, q)`.
    #[inline]
    pub fn color_of(k: i64, q: u32) -> u32 {
        (k.rem_euclid(q as i64)) as u32
    }

    /// Nearest index with the given color (Section 3.3 / Lemma 15):
    /// among `k ≡ c (mod q)`, the closest to `t = (x - offset)/s` is
    /// `k = c + q·round((t - c)/q)`.
    ///
    /// §Perf: the two per-coordinate divisions of the seed form
    /// (`(x−off)/s`, `/q`) are folded into reciprocal multiplies — the
    /// same fold the fused decode loops in [`crate::quant::lq`] use.
    /// Loops should hoist the reciprocals and call
    /// [`Self::decode_index_folded`] directly.
    #[inline]
    pub fn decode_index(&self, color: u32, x_ref: f64, offset: f64, q: u32) -> i64 {
        let qf = q as f64;
        Self::decode_index_folded(color, x_ref, offset, q, 1.0 / (self.s * qf), 1.0 / qf)
    }

    /// [`Self::decode_index`] with the reciprocals precomputed by the
    /// caller: `inv_sq = 1/(s·q)`, `inv_q = 1/q`, so the hot loop is two
    /// multiplies, a round, and an integer reconstruction.
    #[inline]
    pub fn decode_index_folded(
        color: u32,
        x_ref: f64,
        offset: f64,
        q: u32,
        inv_sq: f64,
        inv_q: f64,
    ) -> i64 {
        let c = color as f64;
        let m = ((x_ref - offset) * inv_sq - c * inv_q).round_ties_even();
        color as i64 + (q as i64) * (m as i64)
    }

    /// Full decode: nearest same-color lattice point to `x_ref`, writing
    /// the reconstructed vector into `out`. Reciprocals hoisted once per
    /// call (§Perf).
    pub fn decode(&self, colors: &[u32], x_ref: &[f64], q: u32, out: &mut [f64]) {
        debug_assert_eq!(colors.len(), self.dim());
        let inv_sq = 1.0 / (self.s * q as f64);
        let inv_q = 1.0 / q as f64;
        for i in 0..colors.len() {
            let k = Self::decode_index_folded(colors[i], x_ref[i], self.offset[i], q, inv_sq, inv_q);
            out[i] = self.offset[i] + self.s * k as f64;
        }
    }

    /// ℓ∞ packing radius (= cover radius for the cubic lattice): s/2.
    pub fn packing_radius(&self) -> f64 {
        self.s / 2.0
    }

    /// Decoding success radius under ℓ∞ (Section 9.1): decoding succeeds
    /// whenever `‖x_enc − x_dec‖∞ ≤ (q−1)s/2`.
    pub fn success_radius(&self, q: u32) -> f64 {
        (q as f64 - 1.0) * self.s / 2.0
    }
}

/// Side length from a distance bound `y` (Section 9.1): `s = 2y/(q−1)`
/// guarantees decode success whenever inputs are within ℓ∞ distance `y`.
pub fn side_for_y(y: f64, q: u32) -> f64 {
    assert!(q >= 2);
    2.0 * y / (q as f64 - 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_point_within_half_side() {
        let mut rng = Rng::new(3);
        let lat = CubicLattice::random_offset(64, 0.25, &mut rng);
        let x: Vec<f64> = (0..64).map(|_| rng.uniform(-10.0, 10.0)).collect();
        let mut k = vec![0i64; 64];
        let mut p = vec![0.0; 64];
        lat.nearest_index(&x, &mut k);
        lat.point(&k, &mut p);
        for (xi, pi) in x.iter().zip(&p) {
            assert!((xi - pi).abs() <= 0.125 + 1e-12);
        }
    }

    #[test]
    fn color_nonnegative_for_negative_indices() {
        assert_eq!(CubicLattice::color_of(-1, 8), 7);
        assert_eq!(CubicLattice::color_of(-8, 8), 0);
        assert_eq!(CubicLattice::color_of(-9, 8), 7);
        assert_eq!(CubicLattice::color_of(5, 8), 5);
    }

    #[test]
    fn same_color_points_are_qs_apart() {
        // Lemma 12 specialization: same-color indices differ by multiples
        // of q, so same-color lattice points are ≥ q·s apart in ℓ∞.
        let q = 8u32;
        for k1 in -50i64..50 {
            for k2 in -50i64..50 {
                if k1 != k2 && CubicLattice::color_of(k1, q) == CubicLattice::color_of(k2, q) {
                    assert_eq!((k1 - k2).rem_euclid(q as i64), 0);
                }
            }
        }
    }

    #[test]
    fn decode_recovers_within_success_radius() {
        let mut rng = Rng::new(11);
        let q = 16u32;
        let d = 32;
        for trial in 0..50 {
            let y = 1.0 + trial as f64 * 0.1;
            let s = side_for_y(y, q);
            let lat = CubicLattice::random_offset(d, s, &mut rng);
            let x: Vec<f64> = (0..d).map(|_| rng.uniform(-5.0, 5.0)).collect();
            // Decoder vector within distance y in every coordinate.
            let xv: Vec<f64> = x.iter().map(|xi| xi + rng.uniform(-y, y)).collect();
            let mut k = vec![0i64; d];
            lat.nearest_index(&x, &mut k);
            let colors: Vec<u32> = k.iter().map(|&ki| CubicLattice::color_of(ki, q)).collect();
            let mut z = vec![0.0; d];
            lat.decode(&colors, &xv, q, &mut z);
            let mut zk = vec![0i64; d];
            lat.nearest_index(&z, &mut zk);
            assert_eq!(zk, k, "decode must recover the encoded lattice point");
        }
    }

    #[test]
    fn success_radius_formula() {
        let lat = CubicLattice::centered(4, 0.5);
        assert!((lat.success_radius(9) - 2.0).abs() < 1e-12);
        assert!((side_for_y(2.0, 9) - 0.5).abs() < 1e-12);
    }
}
