//! Quantization library — the paper's contribution plus every baseline it
//! compares against.
//!
//! The central abstraction is [`VectorCodec`]: a (possibly stateful)
//! compressor that turns a `d`-dimensional vector into a [`Message`] of
//! metered bits and reconstructs a vector on the receiving side. Lattice
//! codecs additionally use the *decoder's own vector* (`reference`) to
//! disambiguate the color class — the paper's key mechanism (Section 3.3).
//!
//! Beyond the allocating `encode`/`decode` pair, the trait carries the
//! aggregation hot path: `encode_into`/`decode_into` recycle caller
//! scratch, and [`VectorCodec::decode_accumulate_into`] /
//! [`VectorCodec::decode_accumulate_range`] fuse decode with a weighted
//! accumulate so a leader can fold `n` incoming bitstreams into one O(d)
//! sum without ever materializing the decoded vectors (the streaming-fold
//! data plane of [`crate::coordinator`]).
//!
//! # §Perf — the symmetric encode/decode block-kernel design
//!
//! Both directions of the wire hot path are word-granular and fused, and
//! they mirror each other:
//!
//! * **Decode plane** (PR 2): lattice decodes pull colors through
//!   [`bits::BitReader::read_block`] (one unaligned load per
//!   ⌊64/width⌋ fields) inside a shared `decode_fold` loop whose sink
//!   distinguishes `decode_into` / `decode_accumulate_into` /
//!   `decode_accumulate_range`; [`crate::coordinator::fold_mean_chunked`]
//!   shards `d` across threads via seekable range kernels.
//! * **Encode plane** (this PR's twin): lattice encodes round, color and
//!   pack through [`bits::BitWriter::push_block`] (one accumulator store
//!   per ⌊64/width⌋ fields) inside a shared `encode_fold` loop whose
//!   sink distinguishes `encode` / `encode_into` / `encode_with_point` /
//!   [`VectorCodec::encode_range`]; [`encode_chunked`] shards `d` across
//!   threads at byte-aligned chunk boundaries
//!   ([`VectorCodec::encode_chunk_align`]). The `HD` rotation feeding
//!   RLQSGD's encode is itself single-pass: a cache-blocked multi-radix
//!   FWHT with the sign diagonal fused into the first butterfly layer
//!   and the 1/√d normalization into the last (see [`hadamard`]).
//!
//! * **Worker pool** (this PR): [`encode_chunked`] (and the decode
//!   plane's `fold_mean_chunked`) no longer spawn scoped threads per
//!   call — shards are dispatched to the process-wide
//!   [`crate::pool::ChunkPool`], whose workers are spawned once at first
//!   use and parked between jobs, with `available_parallelism()` queried
//!   once at pool construction. Shard→worker assignment is fixed
//!   (`i mod pool-size`, no stealing) and results return in task order,
//!   so pooling changes wall-clock, never a wire bit — see
//!   [`crate::pool`] §Perf for the lifecycle and
//!   [`encode_chunked_on`] for the across-pool-sizes pin.
//! * **SIMD lanes** (this PR): the innermost block kernels — FWHT
//!   butterflies, the lattice rounding/decode arithmetic, the
//!   `push_block`/`read_block` field loops, and the bulk uniform
//!   conversion — route through [`crate::simd`], which dispatches to
//!   AVX2 `f64x4` lanes when built with `--features simd` on a capable
//!   CPU and is the scalar reference loop otherwise. Dispatch is decided
//!   by a cached runtime probe; every lane op is IEEE-identical to its
//!   scalar twin (see [`crate::simd`] §Perf), so the feature changes
//!   throughput, never a bit.
//!
//! Every fused/blocked/parallel path is **bit-identical** to its scalar
//! ancestor — block kernels repack the same LSB-first stream, the FWHT
//! fusions commute exactly with IEEE rounding, and chunk boundaries land
//! on byte boundaries — pinned by `rust/tests/prop.rs` and the
//! `session_parity` suite, which is what lets sessions pick all of it up
//! automatically through `encode_into` without moving a single wire bit.
//!
//! Implementations:
//!
//! | codec | paper | module | fused fold |
//! |---|---|---|---|
//! | `LatticeQuantizer` (LQSGD) | §9.1 practical scheme | [`lq`] | block kernel + range |
//! | `RotatedLatticeQuantizer` (RLQSGD) | §6 cubic lattice + HD rotation | [`hadamard`] | scratch rotation, fused accumulate |
//! | `D4Quantizer` | §6 future work, checkerboard lattice | [`d4`] | bucket kernel + range |
//! | `ConvexHullEncoder` | Alg 1 theoretical unbiased rounding | [`convex_hull`] | default |
//! | `RobustAgreement` | §5 error detection (Alg 5) | [`robust`] | — |
//! | `SublinearCodec` | §7 (Alg 7–9) | [`sublinear`] | — |
//! | QSGD L2/L∞, Suresh–Hadamard, TernGrad, EF-SignSGD, `full32` | §9 comparators | [`baselines`] | block kernel + range (see [`baselines`] §Perf) |
//! | vQSGD, PowerSGD, Top-K | §9 comparators | [`baselines`] | fused accumulate (Top-K: sparse O(k)) |

pub mod arena;
pub mod baselines;
pub mod bits;
pub mod convex_hull;
pub mod d4;
pub mod hadamard;
pub mod lattice;
pub mod lq;
pub mod robust;
pub mod sublinear;

pub use arena::{PacketArena, PacketReader};
pub use d4::D4Quantizer;
pub use hadamard::RotatedLatticeQuantizer;
pub use lattice::CubicLattice;
pub use lq::LatticeQuantizer;

use crate::rng::Rng;

/// A wire message: concrete bytes plus the exact information content in
/// bits (colors are bit-packed, so `bits <= 8 * bytes.len() < bits + 8`;
/// codecs that also ship side floats count them at 64 bits each).
#[derive(Clone, Debug, PartialEq)]
pub struct Message {
    pub bytes: Vec<u8>,
    pub bits: u64,
}

impl Message {
    pub fn empty() -> Self {
        Message {
            bytes: Vec::new(),
            bits: 0,
        }
    }
}

/// A vector compressor with metered communication.
///
/// `encode` may mutate internal state (error feedback, PowerSGD warm
/// starts). `decode` reconstructs from the message alone plus, for
/// lattice codecs, the receiver's `reference` vector; baselines ignore
/// `reference`.
pub trait VectorCodec: Send {
    /// Human-readable name used in experiment tables.
    fn name(&self) -> String;

    /// Dimension this codec instance is configured for.
    fn dim(&self) -> usize;

    /// Compress `x`. `rng` drives any stochastic rounding.
    fn encode(&mut self, x: &[f64], rng: &mut Rng) -> Message;

    /// Reconstruct from `msg`; `reference` is the decoder's own vector.
    fn decode(&self, msg: &Message, reference: &[f64]) -> Vec<f64>;

    /// Compress `x` into a caller-owned scratch message (§Perf, the
    /// session hot path): implementations reuse `out.bytes`' capacity so
    /// a multi-round loop allocates nothing after its first round. The
    /// default falls back to [`VectorCodec::encode`]; codecs on the round
    /// loop (the lattice family, full precision) override it.
    ///
    /// Must produce bytes and bit count identical to `encode` — the
    /// session parity tests pin this.
    fn encode_into(&mut self, x: &[f64], rng: &mut Rng, out: &mut Message) {
        *out = self.encode(x, rng);
    }

    /// Reconstruct from `msg` into a caller-owned buffer of length
    /// [`VectorCodec::dim`] (zero-alloc counterpart of `decode`; same
    /// values bit-for-bit). Default falls back to `decode` + copy.
    fn decode_into(&self, msg: &Message, reference: &[f64], out: &mut [f64]) {
        let z = self.decode(msg, reference);
        out.copy_from_slice(&z);
    }

    /// Fused decode-accumulate (§Perf, the streaming-fold hot path):
    /// `acc[i] += weight * decode(msg, reference)[i]` in a single pass
    /// over the packed bitstream — the aggregation kernel a leader runs
    /// once per arriving packet, keeping its memory O(d) regardless of
    /// cluster size.
    ///
    /// Must be arithmetically identical (bit-for-bit, IEEE op for op) to
    /// `decode_into` followed by [`crate::linalg::axpy`] — the coordinator
    /// parity tests pin this. The default does exactly that via the
    /// allocating `decode`; the codecs on the round loop (lattice family,
    /// full precision) override it with single-pass fused loops.
    fn decode_accumulate_into(&self, msg: &Message, reference: &[f64], weight: f64, acc: &mut [f64]) {
        let z = self.decode(msg, reference);
        crate::linalg::axpy(acc, weight, &z);
    }

    /// Chunk-restricted fused decode-accumulate: accumulate coordinates
    /// `lo..lo + acc.len()` only, with `reference` the full-length
    /// reference vector. Fixed-width codecs override this with a direct
    /// [`bits::BitReader::seek`] into the stream, which is what lets the
    /// chunk-sharded parallel fold ([`crate::coordinator::fold`]) split
    /// `d` into cache-sized shards folded by independent threads.
    ///
    /// Chunk boundaries must be multiples of [`Self::fold_chunk_align`].
    /// The default decodes the whole vector (allocating) and accumulates
    /// the slice — correct for every codec, including ones like RLQSGD
    /// whose global rotation makes true range decoding impossible.
    fn decode_accumulate_range(
        &self,
        msg: &Message,
        reference: &[f64],
        weight: f64,
        lo: usize,
        acc: &mut [f64],
    ) {
        let z = self.decode(msg, reference);
        for (a, zi) in acc.iter_mut().zip(&z[lo..lo + acc.len()]) {
            *a += weight * zi;
        }
    }

    /// Coordinate alignment required of `decode_accumulate_range` chunk
    /// boundaries (1 for scalar codecs; 4 for the D4 bucket format, whose
    /// parity-implied bit couples the four coordinates of a bucket).
    fn fold_chunk_align(&self) -> usize {
        1
    }

    /// Sequential pre-pass of a chunkable encode. Codecs whose wire
    /// stream depends on *global* per-encode state — a norm / min-max
    /// header over the whole input, pre-drawn stochastic-rounding
    /// uniforms (via [`crate::rng::Rng::fill_uniform`], stream-identical
    /// to the scalar per-coordinate draws), the rotated input
    /// (Suresh–Hadamard), or error-feedback memory (EF-Sign) — compute
    /// and stash it here, once, before any [`Self::encode_range`] call:
    /// `encode_range` takes `&self` and runs concurrently on shards, so
    /// it can touch neither `&mut self` nor the round RNG.
    /// `encode`/`encode_into` call this internally; [`encode_chunked`]
    /// calls it exactly once before sharding. Calling `encode_range`
    /// without a preceding prepare for the same `x` is a contract
    /// violation (the stochastic codecs assert what they can). Default:
    /// no-op — the lattice family's streams have no global state.
    fn encode_prepare(&mut self, x: &[f64], rng: &mut Rng) {
        let _ = (x, rng);
    }

    /// Number of fixed-width wire fields an [`Self::encode_range`]
    /// stream covers — the sharding domain of [`encode_chunked`]. Equal
    /// to [`Self::dim`] for every codec except those that quantize a
    /// *padded transform* of the input (Suresh–Hadamard quantizes the
    /// power-of-two-padded rotated vector, so its field count is the
    /// padded dimension).
    fn wire_fields(&self) -> usize {
        self.dim()
    }

    /// Append the wire fields for field indices `lo..lo + len` (of
    /// [`Self::wire_fields`]; = coordinates for unpadded codecs) of the
    /// prepared input `x` to `w` — the encode twin of
    /// [`Self::decode_accumulate_range`]. Implemented by codecs whose
    /// message is a fixed-width field stream, optionally preceded by a
    /// byte-aligned header: the lattice family (`LatticeQuantizer`,
    /// `D4Quantizer`), `FullPrecision`, and the fixed-width baselines
    /// (QSGD, Suresh–Hadamard, TernGrad, EF-Sign — whose headers are
    /// emitted by the `lo == 0` chunk and whose global state comes from
    /// [`Self::encode_prepare`]). They advertise it through
    /// [`Self::supports_encode_range`], which is what lets the
    /// chunk-parallel [`encode_chunked`] shard a huge gradient's encode
    /// across cores. The only alignment the call itself needs is the
    /// codec's field coupling (D4 buckets: `lo` and `len` multiples of
    /// 4); byte alignment matters *between* streams — when independently
    /// written streams are concatenated, every interior boundary must be
    /// a multiple of [`Self::encode_chunk_align`] (the final, tail run
    /// may be ragged), which is exactly how [`encode_chunked`] cuts its
    /// runs (headers are whole bytes, so they never disturb the
    /// arithmetic).
    ///
    /// There is no generic fallback — a codec with global cross-field
    /// coupling in the stream itself (RLQSGD's rotation happens *before*
    /// quantization of every field, PowerSGD ships matrix factors,
    /// vQSGD's fields are repetitions rather than coordinates) has no
    /// meaningful field sub-stream — so the default panics; gate calls
    /// on `supports_encode_range`.
    fn encode_range(&self, x: &[f64], lo: usize, len: usize, w: &mut bits::BitWriter) {
        let _ = (x, lo, len, w);
        panic!("{} does not support range encoding", self.name());
    }

    /// True if [`Self::encode_range`] is implemented: the message is a
    /// fixed-width field stream, optionally preceded by a whole-byte
    /// header that the `lo == 0` chunk emits (QSGD's norm, Suresh's
    /// min/max, TernGrad's ℓ∞, EF-Sign's scale).
    fn supports_encode_range(&self) -> bool {
        false
    }

    /// Coordinate alignment required of `encode_range` chunk boundaries:
    /// the smallest coordinate count whose fields fill a whole number of
    /// *bytes*, so independently written chunks concatenate into the
    /// sequential bitstream unchanged. Strictly finer than
    /// [`Self::fold_chunk_align`]: decode chunks only have to respect
    /// field coupling (D4 buckets), encode chunks additionally have to
    /// land on byte boundaries (e.g. 8 coordinates at width 3; 8 buckets
    /// = 32 coordinates for D4's odd `4·width − 1`-bit buckets).
    fn encode_chunk_align(&self) -> usize {
        1
    }

    /// True if decoding needs a reference vector within the codec's
    /// guarantee radius (lattice family). Used by the coordinator to
    /// decide which topology invariants to check.
    fn needs_reference(&self) -> bool {
        false
    }
}

/// Chunk-parallel encode for large `d` — the write-side twin of
/// [`crate::coordinator::fold_mean_chunked`], so a single machine with a
/// huge gradient saturates cores: `d` is split into chunks of ~`chunk`
/// coordinates (rounded up to the codec's byte-boundary
/// [`VectorCodec::encode_chunk_align`]), contiguous runs of chunks are
/// dispatched to the parked workers of the process-wide
/// [`crate::pool::ChunkPool`] (sized to `available_parallelism`, queried
/// once at pool construction — no spawn and no OS query per call), and
/// each worker streams its run through [`VectorCodec::encode_range`]
/// into its own writer. Because every run boundary is a byte boundary of the wire
/// format, concatenating the per-thread buffers reproduces the
/// sequential [`VectorCodec::encode_into`] stream **bit-identically** —
/// sharding changes wall-clock, never a wire bit (pinned by the prop
/// tests).
///
/// `out` is recycled like `encode_into`'s scratch: cleared, capacity
/// kept. The sequential [`VectorCodec::encode_prepare`] pre-pass runs
/// exactly once before sharding (headers, bulk stochastic-rounding
/// uniforms, rotations, error feedback — whatever global state the
/// codec's `encode_range` shards read), which is why this takes
/// `&mut C` and the round `rng`. Requires
/// [`VectorCodec::supports_encode_range`] (the lattice family minus
/// RLQSGD — whose global rotation has no field sub-stream — plus full
/// precision and the fixed-width baselines QSGD / Suresh–Hadamard /
/// TernGrad / EF-Sign); panics otherwise.
pub fn encode_chunked<C: VectorCodec + Sync + ?Sized>(
    codec: &mut C,
    x: &[f64],
    rng: &mut Rng,
    out: &mut Message,
    chunk: usize,
) {
    encode_chunked_on(crate::pool::ChunkPool::global(), codec, x, rng, out, chunk)
}

/// [`encode_chunked`] on an explicit [`crate::pool::ChunkPool`] — the
/// plain entry point is this function on the process-wide
/// [`crate::pool::ChunkPool::global`]. Public so the prop tests can pin
/// the guarantee directly: the stitched stream is bit-identical for
/// *every* pool size (sharding is a function of `pool.size()`, and each
/// shard's bytes depend only on its coordinate range).
pub fn encode_chunked_on<C: VectorCodec + Sync + ?Sized>(
    pool: &crate::pool::ChunkPool,
    codec: &mut C,
    x: &[f64],
    rng: &mut Rng,
    out: &mut Message,
    chunk: usize,
) {
    assert!(
        codec.supports_encode_range(),
        "{} does not support range encoding",
        codec.name()
    );
    assert_eq!(x.len(), codec.dim());
    codec.encode_prepare(x, rng);
    let codec: &C = codec;
    // Shard the wire-field domain (= d except for padded-transform
    // codecs, where it is the padded field count).
    let d = codec.wire_fields();
    let align = codec.encode_chunk_align().max(1);
    let chunk = chunk.max(1).div_ceil(align) * align;
    let threads = pool.size();
    let n_chunks = d.div_ceil(chunk).max(1);
    let group = n_chunks.div_ceil(threads) * chunk;
    let bytes = &mut out.bytes;
    bytes.clear();
    out.bits = 0;
    if d <= group {
        // One run: no thread to amortize, encode in place.
        let mut w = bits::BitWriter::reusing(std::mem::take(bytes));
        codec.encode_range(x, 0, d, &mut w);
        let (b, bits) = w.finish();
        *bytes = b;
        out.bits = bits;
        return;
    }
    let runs: Vec<(usize, usize)> = (0..d.div_ceil(group))
        .map(|gi| (gi * group, group.min(d - gi * group)))
        .collect();
    // Shard i goes to parked worker i mod pool-size — fixed assignment,
    // no stealing — and results come back in task order, so the
    // concatenation below is deterministic.
    let tasks: Vec<_> = runs
        .iter()
        .map(|&(lo, len)| {
            move || {
                let mut w = bits::BitWriter::new();
                codec.encode_range(x, lo, len, &mut w);
                w.finish()
            }
        })
        .collect();
    let parts: Vec<(Vec<u8>, u64)> = pool.run_sharded(tasks);
    for (i, (pb, pbits)) in parts.iter().enumerate() {
        debug_assert!(
            i + 1 == parts.len() || pbits % 8 == 0,
            "interior chunk must end on a byte boundary"
        );
        bytes.extend_from_slice(pb);
        out.bits += pbits;
    }
}

/// Round-trip helper used throughout tests and experiments: encode at `u`,
/// decode at `v`, return (reconstruction, bits).
pub fn roundtrip(
    codec: &mut dyn VectorCodec,
    x_u: &[f64],
    x_v: &[f64],
    rng: &mut Rng,
) -> (Vec<f64>, u64) {
    let msg = codec.encode(x_u, rng);
    let bits = msg.bits;
    (codec.decode(&msg, x_v), bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn roundtrip_helper_reports_bits() {
        let mut rng = Rng::new(1);
        let mut codec = LatticeQuantizer::from_y(8, 8, 1.0, &mut rng);
        let x = vec![0.5; 8];
        let (z, bits) = roundtrip(&mut codec, &x, &x, &mut rng);
        assert_eq!(z.len(), 8);
        assert_eq!(bits, 8 * 3); // d * log2(q)
    }

    #[test]
    fn default_into_methods_match_allocating_paths() {
        // A codec without overrides exercises the trait's fallback
        // implementations of encode_into/decode_into (the baselines all
        // override them now, so use the convex-hull encoder).
        let d = 16;
        let mut codec = crate::quant::convex_hull::ConvexHullEncoder::from_y(d, 8, 1.0);
        let x: Vec<f64> = (0..d).map(|i| i as f64 * 0.037 - 0.2).collect();
        let mut rng_a = Rng::new(5);
        let mut rng_b = Rng::new(5);
        let fresh = codec.encode(&x, &mut rng_a);
        let mut scratch = Message::empty();
        codec.encode_into(&x, &mut rng_b, &mut scratch);
        assert_eq!(scratch, fresh);
        let z = codec.decode(&fresh, &x);
        let mut z2 = vec![0.0; d];
        codec.decode_into(&fresh, &x, &mut z2);
        assert_eq!(z, z2);
    }

    /// Sharded encode at several chunk sizes (including chunks smaller
    /// than the alignment quantum and larger than d) must reproduce the
    /// sequential wire message bit for bit, stale scratch included. The
    /// chunked calls replay the encode's RNG stream and pre-encode codec
    /// state from clones, so stochastic and stateful (EF) codecs see the
    /// identical draws and error memory.
    fn check_chunked<C: VectorCodec + Sync + Clone>(codec: &mut C, x: &[f64], rng: &mut Rng) {
        assert!(codec.supports_encode_range(), "{}", codec.name());
        let rng0 = rng.clone();
        let pristine = codec.clone();
        let expect = codec.encode(x, rng);
        for chunk in [1usize, 97, 1024, 100_000] {
            let mut msg = Message {
                bytes: vec![0xEE; 7],
                bits: 56,
            };
            let mut c = pristine.clone();
            encode_chunked(&mut c, x, &mut rng0.clone(), &mut msg, chunk);
            assert_eq!(msg, expect, "{} chunk={chunk}", codec.name());
        }
    }

    #[test]
    fn encode_chunked_bit_identical_to_sequential_encode() {
        let mut shared = Rng::new(61);
        let mut rng = Rng::new(62);
        // LQ at an awkward width (q=8 → 3 bits: byte alignment needs 8
        // coords), D4 (32-coord quantum), full precision, and the
        // header-carrying stochastic baselines, at a dimension that
        // leaves ragged tail chunks (and pads for Suresh–Hadamard).
        let d = 4096 + 32;
        let x: Vec<f64> = (0..d).map(|_| rng.uniform(-40.0, 40.0)).collect();
        check_chunked(
            &mut LatticeQuantizer::from_y(d, 8, 1.0, &mut shared),
            &x,
            &mut rng,
        );
        check_chunked(&mut D4Quantizer::from_y(d, 16, 1.0, &mut shared), &x, &mut rng);
        check_chunked(
            &mut crate::quant::baselines::FullPrecision::new(d),
            &x,
            &mut rng,
        );
        check_chunked(
            &mut crate::quant::baselines::Qsgd::new(d, 8, crate::quant::baselines::QsgdNorm::L2),
            &x,
            &mut rng,
        );
        check_chunked(
            &mut crate::quant::baselines::SureshHadamard::new(d, 8, &mut shared),
            &x,
            &mut rng,
        );
        check_chunked(&mut crate::quant::baselines::TernGrad::new(d), &x, &mut rng);
        check_chunked(&mut crate::quant::baselines::EfSignSgd::new(d), &x, &mut rng);
    }

    #[test]
    #[should_panic(expected = "does not support range encoding")]
    fn encode_chunked_rejects_codecs_without_range_encoding() {
        // vQSGD's fields are repetition samples, not coordinates, so it
        // has no field sub-stream (RLQSGD is ruled out the same way, by
        // its global pre-quantization rotation — and also by `Sync`,
        // which its decode scratch forgoes).
        let mut codec = crate::quant::baselines::VqsgdCrossPolytope::new(16, 4);
        let x = vec![0.0; 16];
        let mut msg = Message::empty();
        encode_chunked(&mut codec, &x, &mut Rng::new(1), &mut msg, 8);
    }

    #[test]
    fn default_decode_accumulate_matches_decode_plus_axpy() {
        // ConvexHullEncoder rides the trait defaults (the baselines all
        // override the fold kernels now).
        let d = 16;
        let mut codec = crate::quant::convex_hull::ConvexHullEncoder::from_y(d, 8, 4.0);
        let x: Vec<f64> = (0..d).map(|i| (i as f64).sin() * 3.0).collect();
        let mut rng = Rng::new(8);
        let msg = codec.encode(&x, &mut rng);
        // Stale accumulator contents must be preserved and added to.
        let mut acc: Vec<f64> = (0..d).map(|i| i as f64 * 0.11 - 0.7).collect();
        let mut expect = acc.clone();
        let z = codec.decode(&msg, &x);
        crate::linalg::axpy(&mut expect, -0.75, &z);
        codec.decode_accumulate_into(&msg, &x, -0.75, &mut acc);
        assert_eq!(acc, expect);
        // Range default: middle chunk only.
        let mut acc_r = vec![1.5; 5];
        let mut expect_r = acc_r.clone();
        for (a, zi) in expect_r.iter_mut().zip(&z[6..11]) {
            *a += 2.0 * zi;
        }
        codec.decode_accumulate_range(&msg, &x, 2.0, 6, &mut acc_r);
        assert_eq!(acc_r, expect_r);
        assert_eq!(codec.fold_chunk_align(), 1);
    }
}
