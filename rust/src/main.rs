//! `dme` — CLI for the lattice-DME reproduction.
//!
//! Subcommands:
//!   dme exp <1..8|tradeoff|dropout|chaos|all> [scale=<f>] [seeds=<n>] [batch=<B>] [addr=<H:P>]
//!                                                             regenerate figures/tables
//!                                                             (`chaos` = hostile-workload harness;
//!                                                             addr= targets an external serve)
//!   dme me  [n=..] [d=..] [q=..] [seed=..] [topology=..] [batch=<B>]
//!                                                             MeanEstimation rounds
//!   dme vr  [n=..] [d=..] [q=..] [seed=..] [topology=..] [robust=0|1] [batch=<B>]
//!                                                             VarianceReduction rounds
//!   dme runtime [graph=<name>]                                PJRT artifact smoke check
//!   dme info                                                  artifact + config summary
//!   dme serve  [addr=127.0.0.1:0] [deadline_ms=2000] [rounds=<N>] [data_dir=<DIR>]
//!              [mem_budget=<BYTES>] [sync=always|close|never]
//!              [screen=off|basic|distance] [conn_deadline_ms=30000] [max_conns=..]
//!              [max_open_rounds=..] [max_open_cohorts=..] [max_resident=<BYTES>]
//!              [rate_burst=<f>] [rate_per_sec=<f>] [retry_after_ms=50]
//!                                                             multi-cohort DME service
//!   dme report addr=<host:port> [cohort=..] [round=..] [client=..] [n=..] [d=..]
//!              [q=..] [y=..] [seed=..] [deadline_ms=..] [value=<f>]
//!                                                             report one vector, await estimate
//!   dme health addr=<host:port>                               per-cohort service stats
//!
//! `topology=` takes `star`, `tree`, `tree:<m>` or `both` (default) and
//! routes through the session API (`DmeBuilder` → `DmeSession`).
//! `batch=` runs B rounds as slots of one `round_batch` call — one
//! worker channel crossing per batch, per-slot results bit-identical to
//! sequential rounds.

use dme::config::RunConfig;
use dme::coordinator::{CodecSpec, DmeBuilder, DmeSession, RoundOutcome, Topology};
use dme::exp::{self, ExpOpts};
use dme::net::cohort::{CohortSpec, CohortTable};
use dme::net::screen::ScreenMode;
use dme::net::service::{fetch_stats, report_round, serve_with_table, RateLimit, ServeOpts};
use dme::rng::Rng;
use dme::sim::summarize;
use dme::store::{DurabilityOpts, SyncPolicy};
use std::time::Duration;

fn parse_kv(args: &[String]) -> Vec<(String, String)> {
    args.iter()
        .filter_map(|a| a.split_once('=').map(|(k, v)| (k.to_string(), v.to_string())))
        .collect()
}

fn usage() -> ! {
    eprintln!(
        "usage: dme <command>\n\
         \n\
         commands:\n\
         \x20 exp <1..8|tradeoff|dropout|chaos|all> [scale=1.0] [seeds=5] [batch=1] [addr=H:P]\n\
         \x20                                                 regenerate paper figures/tables; `chaos` runs\n\
         \x20                                                 the hostile-workload harness (addr= targets an\n\
         \x20                                                 external ephemeral serve, else self-hosted)\n\
         \x20 me  [n=8] [d=64] [q=16] [seed=0] [topology=both] [batch=1]\n\
         \x20                                                 MeanEstimation rounds (star|tree|tree:<m>|both)\n\
         \x20 vr  [n=8] [d=64] [q=16] [seed=0] [topology=star] [robust=1] [batch=1]\n\
         \x20                                                 VarianceReduction rounds\n\
         \x20 runtime [graph=lattice_encode_d128_q8]          PJRT artifact smoke check\n\
         \x20 info                                            artifact + config summary\n\
         \x20 serve  [addr=127.0.0.1:0] [deadline_ms=2000] [rounds=N] [data_dir=DIR]\n\
         \x20        [mem_budget=BYTES] [sync=always|close|never]\n\
         \x20        [screen=off|basic|distance] [conn_deadline_ms=30000] [max_conns=..]\n\
         \x20        [max_open_rounds=..] [max_open_cohorts=..] [max_resident=BYTES]\n\
         \x20        [rate_burst=f] [rate_per_sec=f] [retry_after_ms=50]\n\
         \x20                                                 multi-cohort DME service (prints 'listening on ADDR');\n\
         \x20                                                 data_dir= adds a WAL + crash recovery, mem_budget=\n\
         \x20                                                 spills big rounds to disk, sync= picks fsync policy;\n\
         \x20                                                 screen= + the caps + rate_burst/rate_per_sec harden\n\
         \x20                                                 the edge (see `dme::net` \"Overload & screening\")\n\
         \x20 report addr=H:P [cohort=0] [round=0] [client=0] [n=2] [d=16] [q=64] [y=8]\n\
         \x20        [seed=0] [deadline_ms=0] [value=f]       report one vector, await the round estimate\n\
         \x20 health addr=H:P                                 per-cohort service stats\n\
         \n\
         batch=B runs B rounds as one batched round_batch call (one\n\
         worker crossing per batch; per-slot results bit-identical to\n\
         sequential rounds)"
    );
    std::process::exit(2);
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("");
    match cmd {
        "exp" => cmd_exp(&args[1..]),
        "me" => cmd_me(&args[1..]),
        "vr" => cmd_vr(&args[1..]),
        "runtime" => cmd_runtime(&args[1..]),
        "info" => cmd_info(),
        "serve" => cmd_serve(&args[1..]),
        "report" => cmd_report(&args[1..]),
        "health" => cmd_health(&args[1..]),
        _ => usage(),
    }
}

fn kv_get<'a>(kv: &'a [(String, String)], key: &str) -> Option<&'a str> {
    kv.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

fn kv_parse<T: std::str::FromStr>(kv: &[(String, String)], key: &str, default: T) -> T {
    match kv_get(kv, key) {
        Some(v) => v.parse().unwrap_or_else(|_| {
            eprintln!("bad value '{v}' for {key}");
            usage();
        }),
        None => default,
    }
}

fn cmd_serve(args: &[String]) {
    let kv = parse_kv(args);
    let addr = kv_get(&kv, "addr").unwrap_or("127.0.0.1:0");
    // Overload hardening: every knob defaults to "off", so a bare
    // `dme serve` is bit-identical to the pre-hardening service.
    let rate_limit = kv_get(&kv, "rate_burst").map(|_| RateLimit {
        burst: kv_parse(&kv, "rate_burst", 1.0f64),
        per_sec: kv_parse(&kv, "rate_per_sec", 0.0f64),
    });
    let opts = ServeOpts {
        default_deadline_ms: kv_parse(&kv, "deadline_ms", 2_000u64),
        max_rounds: kv_get(&kv, "rounds").map(|v| {
            v.parse().unwrap_or_else(|_| {
                eprintln!("bad value '{v}' for rounds");
                usage();
            })
        }),
        conn_deadline: Duration::from_millis(kv_parse(&kv, "conn_deadline_ms", 30_000u64)),
        screen: kv_parse(&kv, "screen", ScreenMode::Off),
        max_conns: kv_parse(&kv, "max_conns", usize::MAX),
        max_open_rounds: kv_parse(&kv, "max_open_rounds", usize::MAX),
        max_open_cohorts: kv_parse(&kv, "max_open_cohorts", usize::MAX),
        max_resident_bytes: kv_parse(&kv, "max_resident", usize::MAX),
        rate_limit,
        retry_after_ms: kv_parse(&kv, "retry_after_ms", 50u64),
        ..ServeOpts::default()
    };
    // Durability: `data_dir=` switches on the WAL'd store; `mem_budget=`
    // caps resident accumulator bytes (rounds beyond it spill to on-disk
    // runs); `sync=` picks the fsync policy. The table is built (and any
    // crash recovered) before the listener binds, so clients never reach
    // a half-replayed leader.
    let durability = kv_get(&kv, "data_dir").map(|dir| DurabilityOpts {
        mem_budget: kv_parse(&kv, "mem_budget", usize::MAX),
        sync: kv_parse(&kv, "sync", SyncPolicy::OnClose),
        ..DurabilityOpts::new(dir)
    });
    let table = match &durability {
        Some(d) => {
            let (table, rec) = CohortTable::durable(d).unwrap_or_else(|e| {
                eprintln!("cannot open data_dir {}: {e}", d.data_dir.display());
                std::process::exit(1);
            });
            // Printed before `listening on` so the crash-recovery smoke
            // can scrape both lines in order.
            println!(
                "recovered: reports={} open_rounds={} closed={} wal_bytes={} truncated_tail={}",
                rec.reports_replayed,
                rec.rounds_reopened,
                rec.rounds_closed,
                rec.wal_bytes,
                rec.tail.is_some()
            );
            table
        }
        None => CohortTable::new(),
    };
    let listener = std::net::TcpListener::bind(addr).unwrap_or_else(|e| {
        eprintln!("cannot bind {addr}: {e}");
        std::process::exit(1);
    });
    let local = listener.local_addr().expect("bound listener has an address");
    // The smoke harness scrapes this line for the ephemeral port.
    println!("listening on {local}");
    use std::io::Write as _;
    let _ = std::io::stdout().flush();
    match serve_with_table(listener, opts, table) {
        Ok(s) => println!(
            "served: rounds={} partial={} cohorts={} bits_in={} bits_out={} shed={} quarantined={} peak_resident={}",
            s.rounds_completed,
            s.rounds_partial,
            s.cohorts,
            s.traffic.recv_bits,
            s.traffic.sent_bits,
            s.shed,
            s.quarantined,
            s.peak_resident_bytes
        ),
        Err(e) => {
            eprintln!("serve failed: {e}");
            std::process::exit(1);
        }
    }
}

/// The report CLI's cohort-spec arguments (shared-randomness convention:
/// every client of a cohort must pass identical n/d/q/y/seed).
fn report_spec(kv: &[(String, String)]) -> CohortSpec {
    CohortSpec {
        n: kv_parse(kv, "n", 2usize),
        d: kv_parse(kv, "d", 16usize),
        spec: CodecSpec::Lq {
            q: kv_parse(kv, "q", 64u32),
        },
        y: kv_parse(kv, "y", 8.0f64),
        seed: kv_parse(kv, "seed", 0u64),
    }
}

fn cmd_report(args: &[String]) {
    let kv = parse_kv(args);
    let Some(addr) = kv_get(&kv, "addr") else {
        eprintln!("report needs addr=<host:port>");
        usage();
    };
    let spec = report_spec(&kv);
    let cohort = kv_parse(&kv, "cohort", 0u64);
    let round = kv_parse(&kv, "round", 0u64);
    let client = kv_parse(&kv, "client", 0usize);
    let deadline_ms = kv_parse(&kv, "deadline_ms", 0u32);
    let value = kv_parse(&kv, "value", client as f64);
    let input = vec![value; spec.d];
    match report_round(
        addr,
        cohort,
        round,
        client,
        &spec,
        &input,
        deadline_ms,
        Duration::from_secs(30),
    ) {
        Ok(out) => {
            let mean0 = out.estimate.first().copied().unwrap_or(0.0);
            println!(
                "estimate_ok received={} expected={} partial={} mean0={mean0:.6}",
                out.received, out.expected, out.partial
            );
        }
        Err(e) => {
            eprintln!("report failed: {e}");
            std::process::exit(1);
        }
    }
}

fn cmd_health(args: &[String]) {
    let kv = parse_kv(args);
    let Some(addr) = kv_get(&kv, "addr") else {
        eprintln!("health needs addr=<host:port>");
        usage();
    };
    match fetch_stats(addr, Duration::from_secs(10)) {
        Ok(stats) => {
            println!("cohorts={}", stats.len());
            for s in stats {
                println!(
                    "cohort={} rounds={} partial={} reports={} bits_in={} bits_out={} open={} \
                     shed={} quarantined={} resident={}",
                    s.cohort,
                    s.rounds_completed,
                    s.rounds_partial,
                    s.reports,
                    s.bits_in,
                    s.bits_out,
                    s.open_rounds,
                    s.shed,
                    s.quarantined,
                    s.resident_bytes
                );
            }
        }
        Err(e) => {
            eprintln!("health failed: {e}");
            std::process::exit(1);
        }
    }
}

fn cmd_exp(args: &[String]) {
    let id = args.first().map(String::as_str).unwrap_or("all");
    let mut opts = ExpOpts::default();
    for (k, v) in parse_kv(args) {
        match k.as_str() {
            "scale" => opts.scale = v.parse().unwrap_or(1.0),
            "seeds" => opts.seeds = v.parse().unwrap_or(5),
            "batch" => match v.parse::<usize>() {
                // Same validation as the me/vr path (RunConfig::apply).
                Ok(b) if b >= 1 => opts.batch = b,
                _ => {
                    eprintln!("bad value '{v}' for batch (must be >= 1)");
                    usage();
                }
            },
            "out" => opts.out_dir = Some(v),
            "addr" => opts.addr = Some(v),
            _ => {}
        }
    }
    let ids: Vec<&str> = if id == "all" {
        exp::ALL_IDS.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        match exp::run(id, &opts) {
            Some(report) => println!("{report}"),
            None => {
                eprintln!("unknown experiment '{id}'");
                usage();
            }
        }
    }
}

fn build_cfg(args: &[String]) -> RunConfig {
    let mut cfg = RunConfig {
        n_machines: 8,
        dim: 64,
        q: 16,
        ..Default::default()
    };
    for (k, v) in parse_kv(args) {
        if let Err(e) = cfg.apply(&k, &v) {
            eprintln!("{e}");
            usage();
        }
    }
    cfg
}

fn gen_inputs(cfg: &RunConfig, spread: f64) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(cfg.seed);
    (0..cfg.n_machines)
        .map(|_| {
            (0..cfg.dim)
                .map(|_| 100.0 + rng.uniform(-spread / 2.0, spread / 2.0))
                .collect()
        })
        .collect()
}

/// The topologies a `topology=` argument selects (`both` ⇒ star + tree).
fn topologies(cfg: &RunConfig) -> Vec<Topology> {
    if cfg.topology == "both" {
        return vec![Topology::Star, Topology::Tree { m: cfg.n_machines }];
    }
    match Topology::parse(&cfg.topology, cfg.n_machines) {
        Ok(t) => vec![t],
        Err(e) => {
            eprintln!("{e}");
            usage();
        }
    }
}

fn me_session(cfg: &RunConfig, topology: Topology) -> DmeSession {
    DmeBuilder::new(cfg.n_machines, cfg.dim)
        .topology(topology)
        .codec(CodecSpec::Lq { q: cfg.q })
        .seed(cfg.seed)
        .build()
}

fn print_round(label: &str, out: &RoundOutcome, mu: &[f64]) {
    let s = summarize(&out.round_traffic);
    let stats = match out.leader {
        Some(l) => format!("leader={l}"),
        None => format!("q_used={}", out.q_used.unwrap_or(0)),
    };
    println!(
        "{label:<12}: {stats} agree={} err2={:.3e} max_sent={}b max_recv={}b mean_sent={:.0}b",
        out.agreement,
        dme::linalg::dist2(&out.estimate, mu).powi(2),
        s.max_sent,
        s.max_recv,
        s.mean_sent
    );
}

fn cmd_me(args: &[String]) {
    let cfg = build_cfg(args);
    let y = 1.0;
    let inputs = gen_inputs(&cfg, y);
    let mu = dme::linalg::mean_vecs(&inputs);

    for topology in topologies(&cfg) {
        let mut sess = me_session(&cfg, topology);
        if cfg.batch > 1 {
            // One batched call: B rounds, one worker crossing per machine.
            let slots = vec![inputs.clone(); cfg.batch];
            let ys = vec![y; cfg.batch];
            for out in sess.round_batch_with_y(&slots, &ys) {
                print_round(&format!("{}[{}]", topology.label(), out.round), &out, &mu);
            }
        } else {
            let out = sess.round_with_y(&inputs, y);
            print_round(&topology.label(), &out, &mu);
        }
    }
}

fn cmd_vr(args: &[String]) {
    let cfg = build_cfg(args);
    let sigma = 1.0;
    let mut rng = Rng::new(cfg.seed);
    let nabla: Vec<f64> = (0..cfg.dim).map(|_| 100.0 + rng.next_gaussian()).collect();
    let inputs: Vec<Vec<f64>> = (0..cfg.n_machines)
        .map(|_| {
            nabla
                .iter()
                .map(|v| v + sigma / (cfg.dim as f64).sqrt() * rng.next_gaussian())
                .collect()
        })
        .collect();
    // Robust VR (Algorithm 6) is leader-based; the Chebyshev reduction
    // (Theorem 17) runs MeanEstimation over any configured topology.
    let topology = if cfg.topology == "both" {
        Topology::Star
    } else {
        topologies(&cfg)[0]
    };
    let mut builder = DmeBuilder::new(cfg.n_machines, cfg.dim)
        .topology(topology)
        .codec(CodecSpec::Lq { q: cfg.q })
        .seed(cfg.seed);
    if cfg.robust {
        builder = builder.robust(cfg.q);
    }
    let mut sess = builder.build();
    // batch=B ships B VR rounds through one round_vr_batch call (the
    // Chebyshev reduction batches onto the cluster; robust VR falls back
    // to sequential escalation rounds).
    let outs = if cfg.batch > 1 {
        let slots = vec![inputs.clone(); cfg.batch];
        sess.round_vr_batch(&slots, sigma)
    } else {
        vec![sess.round_vr(&inputs, sigma)]
    };
    let in_var = dme::linalg::dist2(&inputs[0], &nabla).powi(2);
    for out in &outs {
        let s = summarize(&out.round_traffic);
        let out_var = dme::linalg::dist2(&out.estimate, &nabla).powi(2);
        let mut label = if cfg.robust {
            "robust-vr".to_string()
        } else {
            format!("vr/{}", topology.label())
        };
        if cfg.batch > 1 {
            label = format!("{label}[{}]", out.round);
        }
        // Tree rounds have no leader; they report the effective tree-codec
        // color count instead (the tree ignores `q=` — it uses the paper's
        // own ε=y/m², q=m³ parameterization).
        let stats = match out.leader {
            Some(l) => format!("leader={l}"),
            None => format!("q_used={}", out.q_used.unwrap_or(0)),
        };
        println!(
            "{label}: {stats} input_err2={in_var:.3e} output_err2={out_var:.3e} (reduction {:.1}x)",
            in_var / out_var.max(1e-300)
        );
        println!(
            "traffic  : max_sent={}b max_recv={}b mean_sent={:.0}b stage1_rounds={:?}",
            s.max_sent, s.max_recv, s.mean_sent, out.rounds_stage1
        );
    }
}

fn cmd_runtime(args: &[String]) {
    let kv = parse_kv(args);
    let graph = kv
        .iter()
        .find(|(k, _)| k == "graph")
        .map(|(_, v)| v.clone())
        .unwrap_or_else(|| "lattice_encode_d128_q8".to_string());
    match dme::runtime::Engine::discover() {
        Err(e) => {
            eprintln!("runtime unavailable: {e}");
            std::process::exit(1);
        }
        Ok(eng) => {
            println!("platform: {}", eng.platform());
            println!("artifacts: {}", eng.manifest.specs.len());
            let g = eng.load(&graph).expect("load graph");
            println!("loaded '{}' with outputs {:?}", g.name, g.out_shapes);
            // Exercise it with constant inputs of the right shapes.
            let spec = eng.manifest.get(&graph).unwrap().clone();
            let bufs: Vec<Vec<f32>> = spec
                .inputs
                .iter()
                .map(|s| vec![0.1f32; s.iter().product::<usize>().max(1)])
                .collect();
            let inputs: Vec<(&[f32], &[usize])> = bufs
                .iter()
                .zip(&spec.inputs)
                .map(|(b, s)| (b.as_slice(), s.as_slice()))
                .collect();
            let outs = g.run_f32(&inputs).expect("execute");
            println!(
                "executed: {} outputs, first lens {:?}",
                outs.len(),
                outs.iter().take(3).map(|o| o.len()).collect::<Vec<_>>()
            );
        }
    }
}

fn cmd_info() {
    println!("dme — lattice-based distributed mean estimation (ICLR 2021 reproduction)");
    match dme::runtime::find_artifact_dir() {
        Some(d) => println!("artifact dir: {}", d.display()),
        None => println!("artifact dir: NOT FOUND (run `make artifacts`)"),
    }
    println!("experiments : dme exp <1..8|tradeoff|dropout|all>");
}
