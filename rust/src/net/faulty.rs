//! Deterministic, seeded fault injection over any transport.
//!
//! [`FaultyEndpoint`] wraps any [`TransportEndpoint`] — the in-process
//! [`crate::sim::Endpoint`] or a TCP mesh endpoint alike — and injects
//! per-machine, per-round faults drawn from a [`FaultPlan`]. Every fault
//! decision is a pure function of `(plan.seed, machine id, round)`:
//! re-running the same plan over the same protocol reproduces the exact
//! same fault pattern, independently of thread scheduling, wall-clock,
//! or the size of the worker pool. That determinism is what makes the
//! failure-injection suite (`rust/tests/failure_injection.rs`) and the
//! dropout experiment (`crate::exp::dropout`) reproducible.
//!
//! # Fault model: send-side silence
//!
//! Faults act at the **send boundary**. A machine faulted in a round has
//! its outgoing messages dropped, withheld past any deadline, duplicated
//! or corrupted — its receive side is untouched. This models the failure
//! the k-of-n straggler policy must survive: to the rest of the cluster,
//! a machine whose uploads never arrive is indistinguishable from one
//! that crashed, so send-side silence exercises every partial-round code
//! path while keeping the wrapper trivially deterministic. Within a
//! deadline-bounded round, a message delayed past the deadline is
//! indistinguishable from a dropped one on the wire; the wrapper
//! distinguishes the two only in its [`FaultStats`] log.
//!
//! Metering follows what actually crossed the wire: a dropped or
//! withheld message charges neither side (it never reached the
//! transport), a duplicated message charges both sides twice, a
//! corrupted message charges its normal bits.
//!
//! # Round counter
//!
//! [`TransportEndpoint`] has no notion of protocol rounds, so the
//! wrapper keeps an explicit counter: the driver (the session worker
//! loops) calls [`FaultyEndpoint::set_round`] before each round or batch
//! slot, and the wrapper caches the fault decision for `(id, round)`.
//! With no plan attached the wrapper is a transparent pass-through — the
//! session workers always run behind one, and full-participation rounds
//! stay bit-identical to the unwrapped transport (pinned by
//! `rust/tests/session_parity.rs`).

use crate::net::{Packet, Traffic, Transport, TransportEndpoint, TransportError};
use crate::quant::Message;
use crate::rng::{hash2, Rng};
use std::time::Duration;

/// Salt mixed into the plan seed for the per-machine slow-start draw
/// (stable across rounds: a machine that starts slow stays slow until
/// the recovery round).
const SLOW_SALT: u64 = 0x51_0E_57_A7;
/// Salt for the corrupt-payload byte/mask derivation.
const CORRUPT_SALT: u64 = 0xC0_22_4B_7D;

/// The fault injected for one `(machine, round)` cell.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Deliver normally.
    None,
    /// Outgoing messages vanish this round.
    Drop,
    /// Outgoing messages are withheld past any round deadline — on the
    /// wire of a deadline-bounded round this equals [`Fault::Drop`]; the
    /// two are distinguished in [`FaultStats`] only.
    Delay,
    /// Every outgoing message is delivered twice (receivers must dedup).
    Duplicate,
    /// A deterministic byte of each outgoing payload is flipped.
    Corrupt,
    /// The machine crashed at an earlier round: silent from then on.
    Crash,
    /// Slow-start: the machine is delay-faulted in every round before
    /// its recovery round, then runs clean.
    SlowStart,
}

impl Fault {
    /// Does this fault silence the machine's sends entirely? (Its
    /// reports never arrive; the straggler policy sees it as dropped.)
    pub fn silences(self) -> bool {
        matches!(
            self,
            Fault::Drop | Fault::Delay | Fault::Crash | Fault::SlowStart
        )
    }
}

/// Slow-start shape: a seeded subset of machines is delay-faulted in
/// every round `< recover_round`, then recovers for good.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SlowStart {
    /// Probability a given machine is a slow starter (one draw per
    /// machine from the plan seed, stable across rounds).
    pub rate: f64,
    /// First round in which slow starters run clean again.
    pub recover_round: u64,
}

/// A reproducible fault schedule: one seed plus per-kind rates.
///
/// [`FaultPlan::fault_for`] maps every `(machine, round)` cell to a
/// [`Fault`] deterministically; the rates partition a single uniform
/// draw per cell, so raising one rate never reshuffles the cells chosen
/// by another. Crash entries and the slow-start window override the
/// rate draw.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Root seed for every fault decision.
    pub seed: u64,
    /// Per-round probability a machine's sends are dropped.
    pub drop_rate: f64,
    /// Per-round probability a machine's sends are delayed past the
    /// deadline.
    pub delay_rate: f64,
    /// Per-round probability a machine's sends are duplicated.
    pub duplicate_rate: f64,
    /// Per-round probability a machine's payloads are corrupted.
    pub corrupt_rate: f64,
    /// `(machine, round)` entries: the machine is silent from `round` on.
    pub crash_at: Vec<(usize, u64)>,
    /// Optional slow-start window (see [`SlowStart`]).
    pub slow_start: Option<SlowStart>,
}

impl FaultPlan {
    /// A plan that injects nothing (useful as an explicit baseline).
    pub fn none(seed: u64) -> Self {
        FaultPlan {
            seed,
            ..FaultPlan::default()
        }
    }

    /// Pure-dropout plan: each machine independently drops each round
    /// with probability `rate` — the dropout-vs-error experiment's knob.
    pub fn dropout(seed: u64, rate: f64) -> Self {
        assert!((0.0..=1.0).contains(&rate), "dropout rate in [0, 1]");
        FaultPlan {
            seed,
            drop_rate: rate,
            ..FaultPlan::default()
        }
    }

    /// The fault for one `(machine, round)` cell — a pure function of
    /// the plan, so every holder of the plan computes the same schedule.
    pub fn fault_for(&self, machine: usize, round: u64) -> Fault {
        for &(m, r) in &self.crash_at {
            if machine == m && round >= r {
                return Fault::Crash;
            }
        }
        if let Some(ss) = self.slow_start {
            if round < ss.recover_round {
                let draw = Rng::new(hash2(self.seed ^ SLOW_SALT, machine as u64)).next_f64();
                if draw < ss.rate {
                    return Fault::SlowStart;
                }
            }
        }
        let draw = Rng::new(hash2(hash2(self.seed, machine as u64), round)).next_f64();
        let mut lo = 0.0;
        for (rate, fault) in [
            (self.drop_rate, Fault::Drop),
            (self.delay_rate, Fault::Delay),
            (self.duplicate_rate, Fault::Duplicate),
            (self.corrupt_rate, Fault::Corrupt),
        ] {
            if draw < lo + rate {
                return fault;
            }
            lo += rate;
        }
        Fault::None
    }

    /// Is `machine` send-silent in `round`? (Convenience for tests and
    /// experiments computing the expected arrived set of a round.)
    pub fn silences(&self, machine: usize, round: u64) -> bool {
        self.fault_for(machine, round).silences()
    }

    /// The machines of `0..n` whose sends survive `round` — the expected
    /// participant set a k-of-n round should fold (assuming the
    /// coordinator itself is reachable).
    pub fn survivors(&self, n: usize, round: u64) -> Vec<usize> {
        (0..n).filter(|&m| !self.silences(m, round)).collect()
    }
}

/// Per-endpoint tally of injected faults (observability for tests and
/// experiment reports; not part of the wire cost model).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Messages delivered untouched.
    pub clean: u64,
    /// Messages swallowed by a drop fault.
    pub dropped: u64,
    /// Messages withheld by a delay or slow-start fault.
    pub delayed: u64,
    /// Messages delivered twice.
    pub duplicated: u64,
    /// Messages delivered with a flipped payload byte.
    pub corrupted: u64,
    /// Messages swallowed after the machine's crash round.
    pub crashed: u64,
}

impl FaultStats {
    /// Total messages the protocol asked the endpoint to send.
    pub fn attempted(&self) -> u64 {
        self.clean + self.dropped + self.delayed + self.duplicated + self.corrupted + self.crashed
    }
}

/// A [`TransportEndpoint`] wrapper injecting [`FaultPlan`] faults at the
/// send boundary (see the module docs for the model).
pub struct FaultyEndpoint<E: TransportEndpoint> {
    inner: E,
    plan: Option<FaultPlan>,
    round: u64,
    fault: Fault,
    stats: FaultStats,
}

impl<E: TransportEndpoint> FaultyEndpoint<E> {
    /// Transparent wrapper: no plan, every operation delegates untouched.
    pub fn new(inner: E) -> Self {
        FaultyEndpoint {
            inner,
            plan: None,
            round: 0,
            fault: Fault::None,
            stats: FaultStats::default(),
        }
    }

    /// Wrap with a fault plan, starting at round 0.
    pub fn with_plan(inner: E, plan: FaultPlan) -> Self {
        let mut ep = FaultyEndpoint::new(inner);
        ep.plan = Some(plan);
        ep.recompute();
        ep
    }

    /// Advance (or rewind) the wrapper's round counter; the fault for
    /// `(id, round)` is recomputed and applied to every send until the
    /// next call. The session workers call this before each round and
    /// each batch slot.
    pub fn set_round(&mut self, round: u64) {
        self.round = round;
        self.recompute();
    }

    fn recompute(&mut self) {
        let id = self.inner.id();
        self.fault = match &self.plan {
            Some(plan) => plan.fault_for(id, self.round),
            None => Fault::None,
        };
    }

    /// The wrapper's current round counter.
    pub fn round(&self) -> u64 {
        self.round
    }

    /// The fault in effect for this machine at the current round.
    pub fn fault(&self) -> Fault {
        self.fault
    }

    /// The attached plan, if any.
    pub fn plan(&self) -> Option<&FaultPlan> {
        self.plan.as_ref()
    }

    /// Injection tally since construction.
    pub fn stats(&self) -> FaultStats {
        self.stats
    }

    /// Unwrap the inner endpoint.
    pub fn into_inner(self) -> E {
        self.inner
    }

    /// Flip one deterministic payload byte — the byte index and the
    /// mask derive from `(plan seed, id, round)`, so the corruption is
    /// reproducible and always changes at least one bit inside the
    /// metered payload span.
    fn corrupt(&self, mut msg: Message) -> Message {
        let span = msg.bytes.len().min(msg.bits.div_ceil(8) as usize);
        if span == 0 {
            return msg;
        }
        let plan_seed = self.plan.as_ref().map(|p| p.seed).unwrap_or(0);
        let h = hash2(
            hash2(plan_seed ^ CORRUPT_SALT, self.inner.id() as u64),
            self.round,
        );
        let idx = (h % span as u64) as usize;
        let mask = ((h >> 32) as u8) | 0x01;
        msg.bytes[idx] ^= mask;
        msg
    }
}

impl<E: TransportEndpoint> TransportEndpoint for FaultyEndpoint<E> {
    fn id(&self) -> usize {
        self.inner.id()
    }

    fn n(&self) -> usize {
        self.inner.n()
    }

    fn send(&mut self, to: usize, msg: Message) -> Result<(), TransportError> {
        match self.fault {
            Fault::None => {
                self.stats.clean += 1;
                self.inner.send(to, msg)
            }
            Fault::Drop => {
                self.stats.dropped += 1;
                Ok(())
            }
            Fault::Delay | Fault::SlowStart => {
                self.stats.delayed += 1;
                Ok(())
            }
            Fault::Crash => {
                self.stats.crashed += 1;
                Ok(())
            }
            Fault::Duplicate => {
                self.stats.duplicated += 1;
                self.inner.send(to, msg.clone())?;
                self.inner.send(to, msg)
            }
            Fault::Corrupt => {
                self.stats.corrupted += 1;
                let corrupted = self.corrupt(msg);
                self.inner.send(to, corrupted)
            }
        }
    }

    fn recv(&mut self) -> Result<Packet, TransportError> {
        self.inner.recv()
    }

    fn recv_from(&mut self, from: usize) -> Result<Packet, TransportError> {
        self.inner.recv_from(from)
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Packet, TransportError> {
        self.inner.recv_timeout(timeout)
    }

    fn traffic(&self) -> Traffic {
        self.inner.traffic()
    }
}

/// A [`Transport`] factory whose endpoints are all wrapped with the same
/// [`FaultPlan`] — drop-in for [`crate::sim::Cluster`] or the TCP mesh
/// in any transport-generic driver.
pub struct FaultyTransport<T: Transport> {
    inner: T,
    plan: FaultPlan,
}

impl<T: Transport> FaultyTransport<T> {
    pub fn new(inner: T, plan: FaultPlan) -> Self {
        FaultyTransport { inner, plan }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }
}

impl<T: Transport> Transport for FaultyTransport<T> {
    type Endpoint = FaultyEndpoint<T::Endpoint>;

    fn n(&self) -> usize {
        self.inner.n()
    }

    fn open(&mut self) -> Result<Vec<Self::Endpoint>, TransportError> {
        Ok(self
            .inner
            .open()?
            .into_iter()
            .map(|ep| FaultyEndpoint::with_plan(ep, self.plan.clone()))
            .collect())
    }

    fn traffic(&self) -> Vec<Traffic> {
        self.inner.traffic()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Cluster;

    fn msg(bits: u64) -> Message {
        Message {
            bytes: vec![0xAAu8; bits.div_ceil(8) as usize],
            bits,
        }
    }

    #[test]
    fn fault_schedule_is_deterministic_and_seed_sensitive() {
        let plan = FaultPlan {
            seed: 7,
            drop_rate: 0.3,
            delay_rate: 0.1,
            duplicate_rate: 0.1,
            corrupt_rate: 0.1,
            ..FaultPlan::default()
        };
        let grid: Vec<Fault> = (0..8)
            .flat_map(|m| (0..16).map(move |r| (m, r)))
            .map(|(m, r)| plan.fault_for(m, r))
            .collect();
        let again: Vec<Fault> = (0..8)
            .flat_map(|m| (0..16).map(move |r| (m, r)))
            .map(|(m, r)| plan.fault_for(m, r))
            .collect();
        assert_eq!(grid, again, "same plan must yield the same schedule");
        let other = FaultPlan { seed: 8, ..plan.clone() };
        let other_grid: Vec<Fault> = (0..8)
            .flat_map(|m| (0..16).map(move |r| (m, r)))
            .map(|(m, r)| other.fault_for(m, r))
            .collect();
        assert_ne!(grid, other_grid, "a different seed must reshuffle faults");
        // With these rates every kind must actually appear somewhere.
        for want in [Fault::None, Fault::Drop, Fault::Delay, Fault::Duplicate, Fault::Corrupt] {
            assert!(grid.contains(&want), "{want:?} never drawn");
        }
    }

    #[test]
    fn crash_is_permanent_and_slow_start_recovers() {
        let plan = FaultPlan {
            seed: 3,
            crash_at: vec![(2, 5)],
            slow_start: Some(SlowStart { rate: 1.0, recover_round: 4 }),
            ..FaultPlan::default()
        };
        assert_eq!(plan.fault_for(2, 4), Fault::SlowStart);
        for r in 5..40 {
            assert_eq!(plan.fault_for(2, r), Fault::Crash, "round {r}");
        }
        // Every machine is a slow starter at rate 1.0, then recovers.
        for m in 0..4 {
            assert_eq!(plan.fault_for(m, 3), Fault::SlowStart, "machine {m}");
            if m != 2 {
                assert_eq!(plan.fault_for(m, 9), Fault::None, "machine {m}");
            }
        }
        assert_eq!(plan.survivors(4, 3), Vec::<usize>::new());
        assert_eq!(plan.survivors(4, 9), vec![0, 1, 3]);
    }

    #[test]
    fn dropped_sends_never_arrive_and_charge_no_meter() {
        let cluster = Cluster::new(2);
        let mut eps = cluster.endpoints();
        let receiver = eps.pop().expect("endpoint 1");
        let plan = FaultPlan {
            seed: 1,
            drop_rate: 1.0,
            ..FaultPlan::default()
        };
        let mut sender = FaultyEndpoint::with_plan(eps.pop().expect("endpoint 0"), plan);
        assert_eq!(sender.fault(), Fault::Drop);
        sender.send(1, msg(64)).expect("drop swallows the send");
        assert_eq!(sender.stats().dropped, 1);
        assert_eq!(sender.traffic(), Traffic::default(), "nothing crossed the wire");
        assert_eq!(receiver.traffic(), Traffic::default());
        drop(receiver);
    }

    #[test]
    fn duplicate_and_corrupt_deliver_observably() {
        let cluster = Cluster::new(2);
        let mut eps = cluster.endpoints();
        let mut receiver = eps.pop().expect("endpoint 1");
        let plan = FaultPlan {
            seed: 2,
            duplicate_rate: 1.0,
            ..FaultPlan::default()
        };
        let mut sender = FaultyEndpoint::with_plan(eps.pop().expect("endpoint 0"), plan);
        sender.send(1, msg(64)).expect("duplicate sends twice");
        use crate::net::TransportEndpoint as _;
        let a = receiver.recv().expect("first copy");
        let b = receiver.recv().expect("second copy");
        assert_eq!(a.msg, b.msg, "duplicates are identical");

        // Same wire, corrupt fault: payload differs from the original in
        // exactly one byte, deterministically.
        let plan = FaultPlan {
            seed: 2,
            corrupt_rate: 1.0,
            ..FaultPlan::default()
        };
        let mut sender = FaultyEndpoint::with_plan(sender.into_inner(), plan);
        let original = msg(64);
        sender.send(1, original.clone()).expect("corrupt still delivers");
        let got = receiver.recv().expect("corrupted copy");
        assert_eq!(got.msg.bits, original.bits);
        let diff: Vec<usize> = original
            .bytes
            .iter()
            .zip(&got.msg.bytes)
            .enumerate()
            .filter(|(_, (x, y))| x != y)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(diff.len(), 1, "exactly one byte flipped");
        // And the corruption is reproducible.
        sender.send(1, original.clone()).expect("send again");
        let again = receiver.recv().expect("same corruption");
        assert_eq!(again.msg, got.msg);
    }

    #[test]
    fn transparent_wrapper_passes_everything_through() {
        let cluster = Cluster::new(2);
        let mut eps = cluster.endpoints();
        let mut receiver = FaultyEndpoint::new(eps.pop().expect("endpoint 1"));
        let mut sender = FaultyEndpoint::new(eps.pop().expect("endpoint 0"));
        sender.set_round(17);
        assert_eq!(sender.fault(), Fault::None);
        sender.send(1, msg(40)).expect("clean send");
        let p = receiver.recv().expect("clean recv");
        assert_eq!(p.from, 0);
        assert_eq!(p.msg.bits, 40);
        assert_eq!(sender.traffic().sent_bits, 40);
        assert_eq!(receiver.traffic().recv_bits, 40);
    }
}
