//! TCP transport: the in-process cluster's wire contract over real
//! sockets.
//!
//! Frames are the [`crate::quant::PacketArena`] format verbatim
//! ([`super::frame`]), so a machine's upload stream is byte-identical to
//! the arena the batched in-process plane stages. Each pair of machines
//! shares one full-duplex `TcpStream`; a per-peer reader thread decodes
//! frames into the endpoint's receive channel, metering received bits on
//! arrival (the sender meters its own sent bits — each side counts its
//! own ledger, which after a completed round agrees exactly with the
//! both-sides-at-send accounting of [`crate::sim::Endpoint`]).
//!
//! Mesh bring-up is deadlock-free by construction: all listeners are
//! bound before any connect, machine `i` dials every `j < i` (retried
//! on a [`RetrySchedule`] with exponential backoff + jitter) and accepts
//! from every `j > i`; the OS listen backlog absorbs dials that land
//! before the peer reaches its accept phase.

use super::error::TransportError;
use super::frame;
use super::retry::RetrySchedule;
use super::{Meter, Packet, Stash, Traffic, Transport, TransportEndpoint};
use crate::quant::Message;
use crate::rng::hash2;
use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// Handshake magic: "DMEm" (mesh).
const MESH_MAGIC: u32 = u32::from_le_bytes(*b"DMEm");

/// Connection and framing knobs for the TCP transport.
#[derive(Clone, Debug)]
pub struct TcpOpts {
    /// Per-attempt connect timeout.
    pub connect_timeout: Duration,
    /// Overall budget for accepting all higher-id peers during mesh
    /// bring-up.
    pub accept_timeout: Duration,
    /// Socket read timeout once the mesh is up; `None` blocks
    /// indefinitely (receive-side deadlines then come from
    /// [`TransportEndpoint::recv_timeout`], which works regardless).
    pub read_timeout: Option<Duration>,
    /// Retries after the first failed connect attempt.
    pub max_retries: u32,
    /// First backoff delay; doubles per retry up to `backoff_cap`.
    pub backoff_base: Duration,
    pub backoff_cap: Duration,
    /// `Some(seed)`: backoff jitter is a pure function of
    /// `(seed, machine, peer)` — reproducible bring-up schedules for
    /// tests and fault-injection runs. `None` (the production default):
    /// jitter from ambient clock entropy, so independent processes
    /// dialing one address spread out instead of stampeding in lockstep.
    pub jitter_seed: Option<u64>,
    /// Largest acceptable frame payload (see [`frame::MAX_FRAME_BYTES`]).
    pub max_frame: u32,
}

impl Default for TcpOpts {
    fn default() -> Self {
        TcpOpts {
            connect_timeout: Duration::from_secs(5),
            accept_timeout: Duration::from_secs(30),
            read_timeout: None,
            max_retries: 5,
            backoff_base: Duration::from_millis(10),
            backoff_cap: Duration::from_millis(640),
            jitter_seed: None,
            max_frame: frame::MAX_FRAME_BYTES,
        }
    }
}

impl TcpOpts {
    /// The connect retry/backoff knobs as a [`RetrySchedule`] — the
    /// same schedule the coordinator's straggler policy reuses for its
    /// per-round gather windows.
    pub fn retry_schedule(&self) -> RetrySchedule {
        RetrySchedule {
            max_retries: self.max_retries,
            backoff_base: self.backoff_base,
            backoff_cap: self.backoff_cap,
            jitter_seed: self.jitter_seed,
        }
    }
}

fn io_err(e: io::Error) -> TransportError {
    TransportError::from_io(&e)
}

/// Dial `addr` on the options' [`RetrySchedule`], sleeping one jittered
/// backoff window between attempts. `salt` keys the jitter stream (the
/// mesh uses `hash2(id, peer)` so every dial edge is independently
/// reproducible under a seeded schedule).
fn connect_with_retry(
    addr: &SocketAddr,
    opts: &TcpOpts,
    salt: u64,
) -> Result<TcpStream, TransportError> {
    let sched = opts.retry_schedule();
    let mut windows = sched.windows(salt);
    let mut last = String::from("no attempt made");
    for attempt in 0..sched.attempts() {
        match TcpStream::connect_timeout(addr, opts.connect_timeout) {
            Ok(s) => return Ok(s),
            Err(e) => last = e.to_string(),
        }
        if attempt + 1 == sched.attempts() {
            break;
        }
        thread::sleep(windows.next().expect("one window per retry"));
    }
    Err(TransportError::Connect {
        addr: addr.to_string(),
        attempts: sched.attempts(),
        last,
    })
}

fn map_send_err(e: io::Error, to: usize) -> TransportError {
    match e.kind() {
        io::ErrorKind::BrokenPipe
        | io::ErrorKind::ConnectionReset
        | io::ErrorKind::ConnectionAborted
        | io::ErrorKind::NotConnected => TransportError::PeerClosed { peer: to },
        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => {
            TransportError::Timeout { peer: Some(to) }
        }
        _ => io_err(e),
    }
}

/// Frame-decode loop for one peer's stream; meters received bits at
/// arrival and forwards packets (or one terminal error) to the
/// endpoint's channel.
fn reader_loop(
    mut stream: TcpStream,
    from: usize,
    tx: Sender<Result<Packet, TransportError>>,
    meter: Arc<Meter>,
    max_frame: u32,
) {
    loop {
        match frame::read_frame(&mut stream, max_frame) {
            Ok(Some(msg)) => {
                meter.note_recv(msg.bits);
                if tx.send(Ok(Packet { from, msg })).is_err() {
                    return; // endpoint dropped
                }
            }
            Ok(None) => return, // peer closed cleanly between frames
            Err(e) => {
                let e = match e {
                    TransportError::Io { kind, .. }
                        if kind == io::ErrorKind::WouldBlock
                            || kind == io::ErrorKind::TimedOut =>
                    {
                        TransportError::Timeout { peer: Some(from) }
                    }
                    TransportError::Io { kind, .. }
                        if kind == io::ErrorKind::ConnectionReset
                            || kind == io::ErrorKind::ConnectionAborted =>
                    {
                        TransportError::PeerClosed { peer: from }
                    }
                    other => other,
                };
                let _ = tx.send(Err(e));
                return;
            }
        }
    }
}

/// One machine's endpoint of a TCP mesh.
///
/// Satisfies the full [`TransportEndpoint`] contract: per-peer FIFO
/// delivery (TCP ordering + one reader per stream + the shared
/// [`Stash`]), metered bits identical to the in-process reference after
/// any completed exchange, and typed errors for every failure mode.
pub struct TcpEndpoint {
    id: usize,
    n: usize,
    writers: Vec<Option<TcpStream>>,
    rx: Receiver<Result<Packet, TransportError>>,
    readers: Vec<JoinHandle<()>>,
    stash: Stash,
    meter: Arc<Meter>,
    scratch: Vec<u8>,
}

impl TcpEndpoint {
    /// Join an `n`-machine mesh as machine `id`. `addrs[j]` is machine
    /// `j`'s listen address; `listener` is this machine's already-bound
    /// listener (bind *all* listeners before calling this anywhere, or
    /// dial-order retries will be doing real work).
    pub fn mesh(
        id: usize,
        addrs: &[SocketAddr],
        listener: TcpListener,
        opts: &TcpOpts,
    ) -> Result<Self, TransportError> {
        let n = addrs.len();
        assert!(id < n, "machine id out of range");
        let mut streams: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();

        // Dial every lower-id peer and introduce ourselves.
        for (j, addr) in addrs.iter().enumerate().take(id) {
            let mut s = connect_with_retry(addr, opts, hash2(id as u64, j as u64))?;
            let mut hello = [0u8; 12];
            hello[0..4].copy_from_slice(&MESH_MAGIC.to_le_bytes());
            hello[4..8].copy_from_slice(&(id as u32).to_le_bytes());
            hello[8..12].copy_from_slice(&(n as u32).to_le_bytes());
            s.write_all(&hello).map_err(|e| map_send_err(e, j))?;
            streams[j] = Some(s);
        }

        // Accept every higher-id peer, with an overall deadline so a
        // dead peer surfaces as Timeout instead of a hang.
        listener.set_nonblocking(true).map_err(io_err)?;
        let deadline = Instant::now() + opts.accept_timeout;
        let mut pending = n - 1 - id;
        while pending > 0 {
            match listener.accept() {
                Ok((mut s, _)) => {
                    s.set_nonblocking(false).map_err(io_err)?;
                    s.set_read_timeout(Some(opts.connect_timeout)).map_err(io_err)?;
                    let mut hs = [0u8; 12];
                    s.read_exact(&mut hs)
                        .map_err(|e| TransportError::Handshake(format!("hello read: {e}")))?;
                    let magic = u32::from_le_bytes(hs[0..4].try_into().unwrap());
                    let peer = u32::from_le_bytes(hs[4..8].try_into().unwrap()) as usize;
                    let peer_n = u32::from_le_bytes(hs[8..12].try_into().unwrap()) as usize;
                    if magic != MESH_MAGIC {
                        return Err(TransportError::Handshake(format!(
                            "bad magic {magic:#010x}"
                        )));
                    }
                    if peer_n != n {
                        return Err(TransportError::Handshake(format!(
                            "peer believes n = {peer_n}, we have n = {n}"
                        )));
                    }
                    if peer <= id || peer >= n {
                        return Err(TransportError::Handshake(format!(
                            "unexpected dial from machine {peer} (we are {id})"
                        )));
                    }
                    if streams[peer].is_some() {
                        return Err(TransportError::Handshake(format!(
                            "duplicate connection from machine {peer}"
                        )));
                    }
                    streams[peer] = Some(s);
                    pending -= 1;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(TransportError::Timeout { peer: None });
                    }
                    thread::sleep(Duration::from_millis(1));
                }
                Err(e) => return Err(io_err(e)),
            }
        }

        // Uniform socket options, then one reader thread per peer.
        let (tx, rx) = channel();
        let meter = Arc::new(Meter::default());
        let mut readers = Vec::with_capacity(n.saturating_sub(1));
        for (j, slot) in streams.iter().enumerate() {
            if let Some(s) = slot {
                s.set_nodelay(true).map_err(io_err)?;
                s.set_read_timeout(opts.read_timeout).map_err(io_err)?;
                let clone = s.try_clone().map_err(io_err)?;
                let tx = tx.clone();
                let meter = meter.clone();
                let max_frame = opts.max_frame;
                readers.push(
                    thread::Builder::new()
                        .name(format!("tcp-rd-{id}-{j}"))
                        .spawn(move || reader_loop(clone, j, tx, meter, max_frame))
                        .expect("spawn reader"),
                );
            }
        }
        drop(tx);

        Ok(TcpEndpoint {
            id,
            n,
            writers: streams,
            rx,
            readers,
            stash: Stash::new(n),
            meter,
            scratch: Vec::new(),
        })
    }

    /// Shared handle to this machine's traffic meter.
    pub fn meter_handle(&self) -> Arc<Meter> {
        self.meter.clone()
    }

    fn recv_channel(&mut self) -> Result<Packet, TransportError> {
        match self.rx.recv() {
            Ok(item) => item,
            Err(_) => Err(TransportError::Shutdown),
        }
    }
}

impl TransportEndpoint for TcpEndpoint {
    fn id(&self) -> usize {
        self.id
    }

    fn n(&self) -> usize {
        self.n
    }

    fn send(&mut self, to: usize, msg: Message) -> Result<(), TransportError> {
        assert_ne!(to, self.id, "no self-sends");
        // Meter before attempting delivery (same discipline as the
        // in-process reference: a send to a dying peer is still a send).
        self.meter.note_sent(msg.bits);
        let len = u32::try_from(msg.bytes.len()).expect("packet under 4 GiB");
        self.scratch.clear();
        self.scratch.extend_from_slice(&msg.bits.to_le_bytes());
        self.scratch.extend_from_slice(&len.to_le_bytes());
        self.scratch.extend_from_slice(&msg.bytes);
        let w = self.writers[to].as_mut().expect("self slot is the only None");
        w.write_all(&self.scratch).map_err(|e| map_send_err(e, to))
    }

    fn recv(&mut self) -> Result<Packet, TransportError> {
        if let Some(p) = self.stash.pop_earliest() {
            return Ok(p);
        }
        self.recv_channel()
    }

    fn recv_from(&mut self, from: usize) -> Result<Packet, TransportError> {
        if let Some(p) = self.stash.pop_from(from) {
            return Ok(p);
        }
        loop {
            let p = self.recv_channel()?;
            if p.from == from {
                return Ok(p);
            }
            self.stash.push(p);
        }
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Packet, TransportError> {
        if let Some(p) = self.stash.pop_earliest() {
            return Ok(p);
        }
        match self.rx.recv_timeout(timeout) {
            Ok(item) => item,
            Err(RecvTimeoutError::Timeout) => Err(TransportError::Timeout { peer: None }),
            Err(RecvTimeoutError::Disconnected) => Err(TransportError::Shutdown),
        }
    }

    fn traffic(&self) -> Traffic {
        self.meter.snapshot()
    }
}

impl Drop for TcpEndpoint {
    fn drop(&mut self) {
        for w in self.writers.iter().flatten() {
            let _ = w.shutdown(Shutdown::Both);
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Build a full `n`-machine mesh over `127.0.0.1` ephemeral ports:
/// binds all listeners first, then brings up every endpoint
/// concurrently. Returns the endpoints in machine order.
pub fn loopback_mesh(n: usize, opts: &TcpOpts) -> Result<Vec<TcpEndpoint>, TransportError> {
    let mut listeners = Vec::with_capacity(n);
    let mut addrs = Vec::with_capacity(n);
    for _ in 0..n {
        let l = TcpListener::bind(("127.0.0.1", 0)).map_err(io_err)?;
        addrs.push(l.local_addr().map_err(io_err)?);
        listeners.push(l);
    }
    let handles: Vec<_> = listeners
        .into_iter()
        .enumerate()
        .map(|(i, l)| {
            let addrs = addrs.clone();
            let opts = opts.clone();
            thread::Builder::new()
                .name(format!("mesh-up-{i}"))
                .spawn(move || TcpEndpoint::mesh(i, &addrs, l, &opts))
                .expect("spawn mesh bring-up")
        })
        .collect();
    let mut eps = Vec::with_capacity(n);
    for (i, h) in handles.into_iter().enumerate() {
        eps.push(
            h.join()
                .map_err(|_| TransportError::WorkerPanicked { machine: i })??,
        );
    }
    Ok(eps)
}

/// A loopback-TCP cluster as a [`Transport`]: the factory counterpart
/// of [`crate::sim::Cluster`] for socket-backed tests and benches.
pub struct LoopbackMesh {
    n: usize,
    endpoints: Option<Vec<TcpEndpoint>>,
    meters: Vec<Arc<Meter>>,
}

impl LoopbackMesh {
    pub fn new(n: usize, opts: &TcpOpts) -> Result<Self, TransportError> {
        let endpoints = loopback_mesh(n, opts)?;
        let meters = endpoints.iter().map(|e| e.meter_handle()).collect();
        Ok(LoopbackMesh {
            n,
            endpoints: Some(endpoints),
            meters,
        })
    }
}

impl Transport for LoopbackMesh {
    type Endpoint = TcpEndpoint;

    fn n(&self) -> usize {
        self.n
    }

    fn open(&mut self) -> Result<Vec<TcpEndpoint>, TransportError> {
        self.endpoints.take().ok_or_else(|| {
            TransportError::Handshake("loopback mesh endpoints already taken".into())
        })
    }

    fn traffic(&self) -> Vec<Traffic> {
        self.meters.iter().map(|m| m.snapshot()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(bits: u64) -> Message {
        Message {
            bytes: vec![0xA5u8; (bits as usize + 7) / 8],
            bits,
        }
    }

    #[test]
    fn loopback_pair_ping_pong_and_meters() {
        let eps = loopback_mesh(2, &TcpOpts::default()).expect("mesh up");
        let mut it = eps.into_iter();
        let mut a = it.next().unwrap();
        let mut b = it.next().unwrap();
        let h = thread::spawn(move || {
            let p = b.recv_from(0).expect("packet from 0");
            assert_eq!(p.msg.bits, 100);
            b.send(0, msg(200)).expect("reply");
            b.traffic()
        });
        a.send(1, msg(100)).expect("send");
        let p = a.recv_from(1).expect("reply from 1");
        assert_eq!(p.msg.bits, 200);
        let tb = h.join().unwrap();
        let ta = a.traffic();
        assert_eq!(ta.sent_bits, 100);
        assert_eq!(ta.recv_bits, 200);
        assert_eq!(tb.sent_bits, 200);
        assert_eq!(tb.recv_bits, 100);
        assert_eq!((ta.sent_msgs, ta.recv_msgs), (1, 1));
    }

    #[test]
    fn connect_to_dead_port_fails_with_bounded_retries() {
        // Bind then drop: the port is very likely refused immediately.
        let addr = {
            let l = TcpListener::bind(("127.0.0.1", 0)).unwrap();
            l.local_addr().unwrap()
        };
        let opts = TcpOpts {
            connect_timeout: Duration::from_millis(200),
            max_retries: 2,
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(4),
            jitter_seed: Some(1),
            ..TcpOpts::default()
        };
        match connect_with_retry(&addr, &opts, 1) {
            Err(TransportError::Connect { attempts, .. }) => assert_eq!(attempts, 3),
            other => panic!("expected Connect error, got {other:?}"),
        }
    }

    #[test]
    fn mesh_broadcast_reaches_everyone() {
        let eps = loopback_mesh(4, &TcpOpts::default()).expect("mesh up");
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                thread::spawn(move || {
                    if ep.id() == 2 {
                        ep.broadcast(&msg(64)).expect("broadcast");
                    } else {
                        let p = ep.recv().expect("packet");
                        assert_eq!(p.from, 2);
                        assert_eq!(p.msg.bits, 64);
                    }
                    ep.traffic()
                })
            })
            .collect();
        let traffic: Vec<Traffic> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(traffic[2].sent_bits, 3 * 64);
        for (i, t) in traffic.iter().enumerate() {
            if i != 2 {
                assert_eq!(t.recv_bits, 64);
            }
        }
    }
}
