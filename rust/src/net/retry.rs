//! Bounded retry/backoff schedules with optional deterministic jitter.
//!
//! [`RetrySchedule`] is the one description of "try, back off, try
//! again" shared by every retry path in the crate: TCP mesh dialing
//! ([`super::tcp`]) sleeps its windows between connect attempts, and the
//! k-of-n partial rounds ([`crate::coordinator`]) use them as the
//! per-attempt *receive* windows of a gather — wait one window, count a
//! retry, wait the next, until the reports arrive or the round deadline
//! eats the remaining budget.
//!
//! Jitter is full-jitter over the top half of the current delay (each
//! window is uniform in `[delay/2, delay]`, then the delay doubles
//! toward the cap — the exact pattern the TCP transport has always
//! used). With `jitter_seed: Some(seed)` the whole schedule is a pure
//! function of `(seed, salt)` — reproducible retry timing for tests and
//! fault-injection runs. With `None` (the production default) the jitter
//! is drawn from ambient clock entropy, so independent processes
//! retrying against one endpoint spread out instead of stampeding in
//! lockstep.

use crate::rng::{hash2, Rng};
use std::time::Duration;

/// A bounded exponential-backoff schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetrySchedule {
    /// Retries after the first attempt ([`RetrySchedule::attempts`] is
    /// `max_retries + 1`).
    pub max_retries: u32,
    /// First backoff delay; doubles per window up to `backoff_cap`.
    pub backoff_base: Duration,
    pub backoff_cap: Duration,
    /// `Some(seed)`: windows are a pure function of `(seed, salt)`.
    /// `None`: jitter from ambient clock entropy (production default).
    pub jitter_seed: Option<u64>,
}

impl Default for RetrySchedule {
    fn default() -> Self {
        RetrySchedule {
            max_retries: 5,
            backoff_base: Duration::from_millis(10),
            backoff_cap: Duration::from_millis(640),
            jitter_seed: None,
        }
    }
}

impl RetrySchedule {
    /// A fully deterministic schedule (tests, fault-injection runs).
    pub fn deterministic(
        max_retries: u32,
        backoff_base: Duration,
        backoff_cap: Duration,
        seed: u64,
    ) -> Self {
        RetrySchedule {
            max_retries,
            backoff_base,
            backoff_cap,
            jitter_seed: Some(seed),
        }
    }

    /// Total attempts the schedule allows.
    pub fn attempts(&self) -> u32 {
        self.max_retries + 1
    }

    /// The jittered backoff windows for one retried operation. `salt`
    /// distinguishes concurrent operations under one seed (dials to
    /// different peers, gathers in different rounds) so their schedules
    /// are independent but individually reproducible.
    ///
    /// Yields exactly [`RetrySchedule::attempts`] windows: dial-style
    /// users sleep a window *between* attempts (consuming
    /// `max_retries` of them), gather-style users wait out up to all
    /// `attempts()` windows as receive timeouts.
    pub fn windows(&self, salt: u64) -> BackoffWindows {
        let seed = match self.jitter_seed {
            Some(seed) => hash2(seed, salt),
            None => hash2(entropy_seed(), salt),
        };
        BackoffWindows {
            delay: self.backoff_base,
            cap: self.backoff_cap,
            left: self.attempts(),
            rng: Rng::new(seed),
        }
    }
}

/// Ambient-entropy seed for unseeded schedules: the sub-second clock
/// phase is plenty to decorrelate independent retry loops, and it keeps
/// the crate free of OS randomness dependencies.
fn entropy_seed() -> u64 {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.subsec_nanos() as u64 ^ (d.as_secs() << 32))
        .unwrap_or(0x5EED_F411);
    hash2(nanos, 0x7E7_2A11)
}

/// Iterator of jittered, capped, doubling backoff windows (see
/// [`RetrySchedule::windows`]).
pub struct BackoffWindows {
    delay: Duration,
    cap: Duration,
    left: u32,
    rng: Rng,
}

impl Iterator for BackoffWindows {
    type Item = Duration;

    fn next(&mut self) -> Option<Duration> {
        if self.left == 0 {
            return None;
        }
        self.left -= 1;
        let jittered = self.delay.mul_f64(0.5 + 0.5 * self.rng.uniform(0.0, 1.0));
        self.delay = (self.delay * 2).min(self.cap);
        Some(jittered)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.left as usize, Some(self.left as usize))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_windows_are_reproducible_and_salt_sensitive() {
        let sched =
            RetrySchedule::deterministic(4, Duration::from_millis(10), Duration::from_millis(80), 9);
        let a: Vec<Duration> = sched.windows(1).collect();
        let b: Vec<Duration> = sched.windows(1).collect();
        assert_eq!(a, b, "same (seed, salt) must replay the same windows");
        assert_eq!(a.len(), 5, "attempts() windows");
        let c: Vec<Duration> = sched.windows(2).collect();
        assert_ne!(a, c, "different salts must decorrelate");
    }

    #[test]
    fn windows_stay_within_jitter_envelope_and_cap() {
        let base = Duration::from_millis(10);
        let cap = Duration::from_millis(40);
        let sched = RetrySchedule::deterministic(7, base, cap, 123);
        let mut delay = base;
        for w in sched.windows(0) {
            assert!(w >= delay.mul_f64(0.5) && w <= delay, "window {w:?} outside [{:?}/2, {:?}]", delay, delay);
            delay = (delay * 2).min(cap);
        }
        // Far past the doubling horizon every window is capped.
        let tail: Vec<Duration> = sched.windows(0).skip(5).collect();
        for w in tail {
            assert!(w <= cap && w >= cap.mul_f64(0.5));
        }
    }

    #[test]
    fn unseeded_windows_still_respect_the_envelope() {
        let base = Duration::from_millis(2);
        let sched = RetrySchedule {
            max_retries: 3,
            backoff_base: base,
            backoff_cap: Duration::from_millis(8),
            jitter_seed: None,
        };
        let ws: Vec<Duration> = sched.windows(7).collect();
        assert_eq!(ws.len(), 4);
        assert!(ws[0] >= base.mul_f64(0.5) && ws[0] <= base);
    }
}
