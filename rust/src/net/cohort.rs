//! Multi-cohort round state — the leader-side bookkeeping of the DME
//! service, as a pure state machine (no sockets, no threads, no clock of
//! its own; the caller feeds submissions and millisecond timestamps).
//!
//! A **cohort** is an independent group of `n` clients that agreed
//! out-of-band on a [`CohortSpec`] — dimension, codec, distance bound
//! `y` and shared-randomness seed. Each round, every client encodes its
//! own vector and reports `(cohort_id, round_id, client_id, message)`;
//! the table folds arriving reports straight into an O(d) accumulator
//! per open round (the star leader's streaming fold,
//! [`crate::quant::VectorCodec::decode_accumulate_into`]) and closes the
//! round when all `n` reports are in — or when the caller expires it at
//! its deadline, in which case the partial sum over the `k ≤ n` arrived
//! reports is renormalized by `1/k`.
//!
//! # The codec convention
//!
//! Server and clients must decode/encode identically without the server
//! ever seeing a client's raw vector, so the convention is fixed here
//! and shared by both sides ([`cohort_codec`], [`client_encoder_rng`]):
//!
//! - the codec is `spec.build(d, y, seed, round)` — shared randomness
//!   (lattice offset, rotation) is derived from `(seed, round)` exactly
//!   as in-cluster protocols do (Section 9.1's shared-randomness
//!   assumption);
//! - client `c`'s stochastic-rounding stream is
//!   `Rng::new(hash2(hash2(seed, round), c + 1))` — the per-machine
//!   encoder stream of the in-process star round, verbatim;
//! - the decode **reference is the zero vector**: unlike a cluster
//!   machine, the server holds no input of its own, so `y` must be an
//!   ℓ∞ bound on the client vectors *themselves* (distance to 0), not
//!   merely on their pairwise spread.
//!
//! Stateful codecs (EF-SignSGD, PowerSGD, Top-K) carry cross-round error
//! memory that a stateless report protocol cannot reproduce; the table
//! rejects them.

use super::Traffic;
use crate::coordinator::CodecSpec;
use crate::quant::{Message, VectorCodec};
use crate::rng::{hash2, Rng};
use std::collections::hash_map::Entry;
use std::collections::HashMap;

/// Identity of one cohort round.
#[derive(Clone, Copy, Debug, Hash, PartialEq, Eq, PartialOrd, Ord)]
pub struct CohortKey {
    pub cohort: u64,
    pub round: u64,
}

/// What a cohort's clients agreed on out-of-band. Every report for one
/// `(cohort, round)` must carry the identical spec.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CohortSpec {
    /// Expected number of reporting clients.
    pub n: usize,
    /// Vector dimension.
    pub d: usize,
    /// Compressor; stateful specs are rejected (see module docs).
    pub spec: CodecSpec,
    /// The codec's distance bound — an ℓ∞ bound on the client vectors
    /// themselves (the decode reference is the zero vector).
    pub y: f64,
    /// Shared-randomness seed.
    pub seed: u64,
}

/// The shared codec for one cohort round — both the server's decoder and
/// every client's encoder (the shared-randomness convention).
pub fn cohort_codec(spec: &CohortSpec, round: u64) -> Box<dyn VectorCodec> {
    spec.spec.build(spec.d, spec.y, spec.seed, round)
}

/// Client `client`'s private stochastic-rounding stream for `round` —
/// the per-machine encoder stream of the in-process star round.
pub fn client_encoder_rng(seed: u64, round: u64, client: usize) -> Rng {
    Rng::new(hash2(hash2(seed, round), client as u64 + 1))
}

/// A closed round's result.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundResult {
    /// Mean over the reports that arrived: `(Σ decoded) / received`.
    pub estimate: Vec<f64>,
    /// How many of the expected reports arrived.
    pub received: usize,
    pub expected: usize,
    /// `received < expected` — the round closed at its deadline.
    pub partial: bool,
}

/// Outcome of one [`CohortTable::submit`].
#[derive(Debug)]
pub enum Submit {
    /// Folded in; the round is still waiting for more reports.
    Pending { received: usize, expected: usize },
    /// This report completed the round.
    Complete(RoundResult),
    /// The round already closed (at its deadline or with `n` reports);
    /// the cached result is returned so late clients still converge.
    Late(RoundResult),
    /// The report was refused and not folded.
    Rejected(String),
}

/// Live per-cohort accounting for the health endpoint, in the paper's
/// per-machine bit-cost units (framing excluded — see `net` docs).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CohortStats {
    pub cohort: u64,
    pub rounds_completed: u64,
    pub rounds_partial: u64,
    pub reports: u64,
    /// Client→leader bits: the sum of accepted reports' `msg.bits`.
    pub bits_in: u64,
    /// Leader→client bits: `64·d` per estimate recipient.
    pub bits_out: u64,
    pub open_rounds: u32,
}

/// One open round's fold state.
struct OpenRound {
    spec: CohortSpec,
    codec: Box<dyn VectorCodec>,
    /// Zero reference vector for decoding (see module docs).
    zeros: Vec<f64>,
    /// Streaming sum of decoded reports.
    acc: Vec<f64>,
    got: Vec<bool>,
    received: usize,
    /// Absolute deadline, caller's millisecond clock.
    deadline_ms: u64,
}

impl OpenRound {
    fn close(&mut self) -> RoundResult {
        let k = self.received.max(1) as f64;
        let inv_k = 1.0 / k;
        let estimate = self.acc.iter().map(|&a| inv_k * a).collect();
        RoundResult {
            estimate,
            received: self.received,
            expected: self.spec.n,
            partial: self.received < self.spec.n,
        }
    }
}

/// How many closed-round results to keep for late clients before the
/// oldest are evicted.
const FINISHED_CACHE_CAP: usize = 4096;

/// The leader-side table of all cohorts' open and recently-closed
/// rounds.
#[derive(Default)]
pub struct CohortTable {
    open: HashMap<CohortKey, OpenRound>,
    finished: HashMap<CohortKey, RoundResult>,
    /// FIFO of `finished` keys for bounded-memory eviction.
    finished_order: std::collections::VecDeque<CohortKey>,
    stats: HashMap<u64, CohortStats>,
}

impl CohortTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of rounds currently accumulating reports.
    pub fn open_rounds(&self) -> usize {
        self.open.len()
    }

    /// Fold one client report into its round. `now_ms` is the caller's
    /// monotonic millisecond clock; a *new* round's deadline is set to
    /// `now_ms + deadline_ms` (the first report opens the round).
    pub fn submit(
        &mut self,
        key: CohortKey,
        spec: &CohortSpec,
        client: usize,
        msg: &Message,
        now_ms: u64,
        deadline_ms: u64,
    ) -> Submit {
        if let Some(done) = self.finished.get(&key) {
            return Submit::Late(done.clone());
        }
        if spec.n == 0 || spec.d == 0 {
            return Submit::Rejected("cohort spec must have n >= 1 and d >= 1".into());
        }
        if spec.spec.is_stateful() {
            return Submit::Rejected(format!(
                "stateful codec {} cannot serve stateless cohort reports",
                spec.spec.label()
            ));
        }
        if client >= spec.n {
            return Submit::Rejected(format!(
                "client id {client} out of range for cohort of n={}",
                spec.n
            ));
        }
        let round = match self.open.entry(key) {
            Entry::Occupied(e) => {
                let r = e.into_mut();
                if r.spec != *spec {
                    return Submit::Rejected(format!(
                        "spec mismatch: round opened with n={} d={} {}, report carries n={} d={} {}",
                        r.spec.n,
                        r.spec.d,
                        r.spec.spec.label(),
                        spec.n,
                        spec.d,
                        spec.spec.label()
                    ));
                }
                r
            }
            Entry::Vacant(e) => {
                let d = spec.d;
                let s = self.stats.entry(key.cohort).or_insert_with(|| CohortStats {
                    cohort: key.cohort,
                    ..CohortStats::default()
                });
                s.open_rounds += 1;
                e.insert(OpenRound {
                    spec: *spec,
                    codec: cohort_codec(spec, key.round),
                    zeros: vec![0.0; d],
                    acc: vec![0.0; d],
                    got: vec![false; spec.n],
                    received: 0,
                    deadline_ms: now_ms.saturating_add(deadline_ms),
                })
            }
        };
        if round.got[client] {
            return Submit::Rejected(format!("duplicate report from client {client}"));
        }
        round.codec.decode_accumulate_into(msg, &round.zeros, 1.0, &mut round.acc);
        round.got[client] = true;
        round.received += 1;
        let stats = self.stats.get_mut(&key.cohort).expect("stats entry exists");
        stats.reports += 1;
        stats.bits_in += msg.bits;
        if round.received == round.spec.n {
            let result = self.close_round(key, false);
            Submit::Complete(result)
        } else {
            Submit::Pending {
                received: round.received,
                expected: round.spec.n,
            }
        }
    }

    /// Close every open round whose deadline has passed, renormalizing
    /// its partial sum over the reports that arrived. Returns the closed
    /// rounds (every open round holds ≥ 1 report — the first report is
    /// what opens it).
    pub fn expire(&mut self, now_ms: u64) -> Vec<(CohortKey, RoundResult)> {
        let mut due: Vec<CohortKey> = self
            .open
            .iter()
            .filter(|(_, r)| r.deadline_ms <= now_ms)
            .map(|(k, _)| *k)
            .collect();
        due.sort_unstable();
        due.into_iter()
            .map(|k| {
                let r = self.close_round(k, true);
                (k, r)
            })
            .collect()
    }

    /// Charge `recipients` estimate deliveries (64·d bits each — the
    /// leader→client leg) to a cohort's ledger. The service calls this
    /// as it actually writes responses, so the meters record what was
    /// transferred, not what was hoped for.
    pub fn note_estimates_sent(&mut self, cohort: u64, d: usize, recipients: usize) {
        if let Some(s) = self.stats.get_mut(&cohort) {
            s.bits_out += 64 * d as u64 * recipients as u64;
        }
    }

    /// Per-cohort accounting, sorted by cohort id.
    pub fn stats(&self) -> Vec<CohortStats> {
        let mut v: Vec<CohortStats> = self.stats.values().copied().collect();
        v.sort_unstable_by_key(|s| s.cohort);
        v
    }

    /// Aggregate traffic over all cohorts, from the server's seat (in =
    /// received, out = sent).
    pub fn total_traffic(&self) -> Traffic {
        let mut t = Traffic::default();
        for s in self.stats.values() {
            t.recv_bits += s.bits_in;
            t.recv_msgs += s.reports;
            t.sent_bits += s.bits_out;
        }
        t
    }

    fn close_round(&mut self, key: CohortKey, partial_close: bool) -> RoundResult {
        let mut round = self.open.remove(&key).expect("closing an open round");
        let result = round.close();
        let s = self.stats.get_mut(&key.cohort).expect("stats entry exists");
        s.open_rounds -= 1;
        s.rounds_completed += 1;
        if partial_close && result.partial {
            s.rounds_partial += 1;
        }
        if self.finished.len() >= FINISHED_CACHE_CAP {
            if let Some(old) = self.finished_order.pop_front() {
                self.finished.remove(&old);
            }
        }
        self.finished.insert(key, result.clone());
        self.finished_order.push_back(key);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(n: usize, d: usize) -> CohortSpec {
        CohortSpec {
            n,
            d,
            spec: CodecSpec::Lq { q: 64 },
            y: 8.0,
            seed: 42,
        }
    }

    fn encode(cs: &CohortSpec, round: u64, client: usize, x: &[f64]) -> Message {
        let mut codec = cohort_codec(cs, round);
        let mut rng = client_encoder_rng(cs.seed, round, client);
        codec.encode(x, &mut rng)
    }

    /// Reference mean: decode each report against zeros with the shared
    /// codec, sum in submission order, divide by k.
    fn reference_mean(cs: &CohortSpec, round: u64, reports: &[(usize, Message)]) -> Vec<f64> {
        let codec = cohort_codec(cs, round);
        let zeros = vec![0.0; cs.d];
        let mut acc = vec![0.0; cs.d];
        for (_, m) in reports {
            codec.decode_accumulate_into(m, &zeros, 1.0, &mut acc);
        }
        let inv = 1.0 / reports.len() as f64;
        acc.iter().map(|&a| inv * a).collect()
    }

    #[test]
    fn full_round_completes_with_renormalized_mean() {
        let cs = spec(3, 8);
        let key = CohortKey { cohort: 5, round: 0 };
        let inputs: Vec<Vec<f64>> = (0..3).map(|i| vec![1.0 + i as f64; 8]).collect();
        let reports: Vec<(usize, Message)> = inputs
            .iter()
            .enumerate()
            .map(|(c, x)| (c, encode(&cs, 0, c, x)))
            .collect();
        let mut table = CohortTable::new();
        for (c, m) in &reports[..2] {
            match table.submit(key, &cs, *c, m, 0, 1000) {
                Submit::Pending { received, expected } => {
                    assert_eq!((received, expected), (c + 1, 3));
                }
                other => panic!("expected Pending, got {other:?}"),
            }
        }
        let result = match table.submit(key, &cs, 2, &reports[2].1, 0, 1000) {
            Submit::Complete(r) => r,
            other => panic!("expected Complete, got {other:?}"),
        };
        assert_eq!(result.received, 3);
        assert!(!result.partial);
        assert_eq!(result.estimate, reference_mean(&cs, 0, &reports));
        // True mean is 2.0 per coordinate; q=64 at y=8 keeps error small.
        for &v in &result.estimate {
            assert!((v - 2.0).abs() < 0.3, "estimate {v} far from 2.0");
        }
        // Late duplicate gets the cached result back.
        match table.submit(key, &cs, 0, &reports[0].1, 5, 1000) {
            Submit::Late(r) => assert_eq!(r, result),
            other => panic!("expected Late, got {other:?}"),
        }
    }

    #[test]
    fn deadline_expiry_renormalizes_partial_mean_k_of_n() {
        let cs = spec(4, 6);
        let key = CohortKey { cohort: 9, round: 3 };
        // Only clients 0 and 2 of 4 report.
        let xs = [vec![4.0; 6], vec![-2.0; 6]];
        let reports: Vec<(usize, Message)> = [(0usize, &xs[0]), (2usize, &xs[1])]
            .iter()
            .map(|&(c, x)| (c, encode(&cs, 3, c, x)))
            .collect();
        let mut table = CohortTable::new();
        for (c, m) in &reports {
            match table.submit(key, &cs, *c, m, 100, 50) {
                Submit::Pending { .. } => {}
                other => panic!("expected Pending, got {other:?}"),
            }
        }
        assert!(table.expire(149).is_empty(), "deadline not yet reached");
        let closed = table.expire(150);
        assert_eq!(closed.len(), 1);
        let (k, result) = &closed[0];
        assert_eq!(*k, key);
        assert_eq!(result.received, 2);
        assert_eq!(result.expected, 4);
        assert!(result.partial);
        // Renormalized over k=2 arrived reports, not n=4.
        assert_eq!(result.estimate, reference_mean(&cs, 3, &reports));
        for &v in &result.estimate {
            assert!((v - 1.0).abs() < 0.3, "partial mean {v} far from 1.0");
        }
        let stats = table.stats();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].rounds_partial, 1);
        assert_eq!(stats[0].open_rounds, 0);
    }

    #[test]
    fn rejects_bad_reports_without_corrupting_state() {
        let cs = spec(2, 4);
        let key = CohortKey { cohort: 1, round: 0 };
        let m = encode(&cs, 0, 0, &[1.0; 4]);
        let mut table = CohortTable::new();
        // Stateful codec refused.
        let bad = CohortSpec {
            spec: CodecSpec::EfSign,
            ..cs
        };
        assert!(matches!(
            table.submit(key, &bad, 0, &m, 0, 100),
            Submit::Rejected(_)
        ));
        // Client out of range refused.
        assert!(matches!(
            table.submit(key, &cs, 2, &m, 0, 100),
            Submit::Rejected(_)
        ));
        assert!(matches!(
            table.submit(key, &cs, 0, &m, 0, 100),
            Submit::Pending { .. }
        ));
        // Duplicate client refused, round still open with 1 report.
        assert!(matches!(
            table.submit(key, &cs, 0, &m, 0, 100),
            Submit::Rejected(_)
        ));
        // Spec mismatch against the opened round refused.
        let other = CohortSpec { y: 2.0, ..cs };
        assert!(matches!(
            table.submit(key, &other, 1, &m, 0, 100),
            Submit::Rejected(_)
        ));
        assert_eq!(table.open_rounds(), 1);
        let stats = table.stats();
        assert_eq!(stats[0].reports, 1);
    }

    #[test]
    fn many_cohorts_multiplex_independently() {
        let cs = spec(2, 4);
        let mut table = CohortTable::new();
        let mut results = Vec::new();
        for cohort in 0..32u64 {
            let key = CohortKey { cohort, round: 7 };
            let x0 = vec![cohort as f64 * 0.1; 4];
            let x1 = vec![cohort as f64 * 0.3; 4];
            let m0 = encode(&cs, 7, 0, &x0);
            let m1 = encode(&cs, 7, 1, &x1);
            assert!(matches!(
                table.submit(key, &cs, 0, &m0, 0, 100),
                Submit::Pending { .. }
            ));
            match table.submit(key, &cs, 1, &m1, 0, 100) {
                Submit::Complete(r) => results.push((cohort, r)),
                other => panic!("expected Complete, got {other:?}"),
            }
        }
        for (cohort, r) in results {
            let want = cohort as f64 * 0.2;
            for &v in &r.estimate {
                assert!((v - want).abs() < 0.2, "cohort {cohort}: {v} vs {want}");
            }
        }
        assert_eq!(table.open_rounds(), 0);
        assert_eq!(table.stats().len(), 32);
        let t = table.total_traffic();
        assert_eq!(t.recv_msgs, 64);
        assert!(t.recv_bits > 0);
    }
}
