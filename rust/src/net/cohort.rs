//! Multi-cohort round state — the leader-side bookkeeping of the DME
//! service, as a pure state machine (no sockets, no threads, no clock of
//! its own; the caller feeds submissions and millisecond timestamps).
//!
//! A **cohort** is an independent group of `n` clients that agreed
//! out-of-band on a [`CohortSpec`] — dimension, codec, distance bound
//! `y` and shared-randomness seed. Each round, every client encodes its
//! own vector and reports `(cohort_id, round_id, client_id, message)`;
//! the table folds arriving reports straight into an O(d) accumulator
//! per open round (the star leader's streaming fold,
//! [`crate::quant::VectorCodec::decode_accumulate_into`]) and closes the
//! round when all `n` reports are in — or when the caller expires it at
//! its deadline, in which case the partial sum over the `k ≤ n` arrived
//! reports is renormalized by `1/k`.
//!
//! # The codec convention
//!
//! Server and clients must decode/encode identically without the server
//! ever seeing a client's raw vector, so the convention is fixed here
//! and shared by both sides ([`cohort_codec`], [`client_encoder_rng`]):
//!
//! - the codec is `spec.build(d, y, seed, round)` — shared randomness
//!   (lattice offset, rotation) is derived from `(seed, round)` exactly
//!   as in-cluster protocols do (Section 9.1's shared-randomness
//!   assumption);
//! - client `c`'s stochastic-rounding stream is
//!   `Rng::new(hash2(hash2(seed, round), c + 1))` — the per-machine
//!   encoder stream of the in-process star round, verbatim;
//! - the decode **reference is the zero vector**: unlike a cluster
//!   machine, the server holds no input of its own, so `y` must be an
//!   ℓ∞ bound on the client vectors *themselves* (distance to 0), not
//!   merely on their pairwise spread.
//!
//! Stateful codecs (EF-SignSGD, PowerSGD, Top-K) carry cross-round error
//! memory that a stateless report protocol cannot reproduce; the table
//! rejects them.
//!
//! # Durability
//!
//! A table built with [`CohortTable::durable`] is backed by a
//! [`crate::store::Store`]: every accepted report is appended to a
//! checksummed write-ahead log *before* it is folded, and open rounds
//! whose accumulators exceed the configured memory budget spill to
//! on-disk runs (exact `f64` images; later reports queue as pending
//! frames and fold in arrival order at compaction/close, so the result
//! is bit-identical to the all-in-RAM fold). After a crash, `durable`
//! replays the log and resumes every open round exactly where it
//! stopped — the renormalized partial means match an uninterrupted
//! leader bit for bit. See the [`crate::store`] docs for the formats
//! and the fsync policy trade-off.

use super::screen::{screen_decoded, RoundScreen, ScreenMode, ScreenStats, DEFAULT_SLACK};
use super::Traffic;
use crate::coordinator::CodecSpec;
use crate::quant::{Message, VectorCodec};
use crate::rng::{hash2, Rng};
use crate::store::{DurabilityOpts, RunImage, Store, StoreError, TailTruncation, WalRecord};
use std::collections::hash_map::Entry;
use std::collections::HashMap;

/// Identity of one cohort round.
#[derive(Clone, Copy, Debug, Hash, PartialEq, Eq, PartialOrd, Ord)]
pub struct CohortKey {
    pub cohort: u64,
    pub round: u64,
}

/// What a cohort's clients agreed on out-of-band. Every report for one
/// `(cohort, round)` must carry the identical spec.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CohortSpec {
    /// Expected number of reporting clients.
    pub n: usize,
    /// Vector dimension.
    pub d: usize,
    /// Compressor; stateful specs are rejected (see module docs).
    pub spec: CodecSpec,
    /// The codec's distance bound — an ℓ∞ bound on the client vectors
    /// themselves (the decode reference is the zero vector).
    pub y: f64,
    /// Shared-randomness seed.
    pub seed: u64,
}

/// The shared codec for one cohort round — both the server's decoder and
/// every client's encoder (the shared-randomness convention).
pub fn cohort_codec(spec: &CohortSpec, round: u64) -> Box<dyn VectorCodec> {
    spec.spec.build(spec.d, spec.y, spec.seed, round)
}

/// Client `client`'s private stochastic-rounding stream for `round` —
/// the per-machine encoder stream of the in-process star round.
pub fn client_encoder_rng(seed: u64, round: u64, client: usize) -> Rng {
    Rng::new(hash2(hash2(seed, round), client as u64 + 1))
}

/// A closed round's result.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundResult {
    /// Mean over the reports that arrived: `(Σ decoded) / received`.
    pub estimate: Vec<f64>,
    /// How many of the expected reports arrived.
    pub received: usize,
    pub expected: usize,
    /// `received < expected` — the round closed at its deadline.
    pub partial: bool,
}

/// Outcome of one [`CohortTable::submit`].
#[derive(Debug)]
pub enum Submit {
    /// Folded in; the round is still waiting for more reports.
    Pending { received: usize, expected: usize },
    /// This report completed the round.
    Complete(RoundResult),
    /// The round already closed (at its deadline or with `n` reports);
    /// the cached result is returned so late clients still converge.
    Late(RoundResult),
    /// The report was refused and not folded.
    Rejected(String),
    /// Load-shed: refused by admission control (open-round/cohort caps,
    /// resident-byte budget) or by the pre-decode frame screen. The
    /// report never touched the WAL or the accumulator; the client
    /// should back off `retry_after_ms` and retry.
    Shed { reason: String, retry_after_ms: u64 },
    /// Screened out after decoding: the values were implausible
    /// (NaN/Inf, or past the distance filter). Not retryable — the
    /// payload itself is bad. The accumulator and WAL are untouched.
    Quarantined(String),
}

/// Live per-cohort accounting for the health endpoint, in the paper's
/// per-machine bit-cost units (framing excluded — see `net` docs).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CohortStats {
    pub cohort: u64,
    pub rounds_completed: u64,
    pub rounds_partial: u64,
    pub reports: u64,
    /// Client→leader bits: the sum of accepted reports' `msg.bits`.
    pub bits_in: u64,
    /// Leader→client bits: `64·d` per estimate recipient.
    pub bits_out: u64,
    pub open_rounds: u32,
    /// Reports refused before decode: admission control, rate limiting
    /// (attributed by the service via [`CohortTable::note_shed`]) or
    /// the frame-coherence screen.
    pub shed: u64,
    /// Reports screened out after decoding (NaN/Inf or the distance
    /// filter) — see [`super::screen`].
    pub quarantined: u64,
    /// Resident accumulator bytes currently held for this cohort's open
    /// rounds (filled by [`CohortTable::stats`] at read time).
    pub resident_bytes: u64,
}

impl CohortStats {
    /// The screening view of this cohort's ledger.
    pub fn screen_stats(&self) -> ScreenStats {
        ScreenStats {
            accepted: self.reports,
            shed: self.shed,
            quarantined: self.quarantined,
        }
    }
}

/// Where one open round's accumulator lives.
enum AccState {
    /// The streaming fold, in RAM — the only state a plain table uses.
    Ram {
        codec: Box<dyn VectorCodec>,
        /// Zero reference vector for decoding (see module docs).
        zeros: Vec<f64>,
        /// Streaming sum of decoded reports.
        acc: Vec<f64>,
    },
    /// The fold so far is sealed in on-disk run `seq`; reports that
    /// arrived after the spill wait as pending frames. Compaction and
    /// close load the image back and fold the pending frames in arrival
    /// order — the identical left-to-right addition sequence as `Ram`,
    /// hence bit-identical results.
    Spilled {
        seq: u64,
        pending: Vec<Message>,
        /// Approximate resident bytes of `pending`, against the budget.
        pending_bytes: usize,
    },
}

/// One open round's fold state.
struct OpenRound {
    spec: CohortSpec,
    state: AccState,
    got: Vec<bool>,
    received: usize,
    /// Absolute deadline, caller's millisecond clock.
    deadline_ms: u64,
    /// Cached size probe for screening (built lazily on the first
    /// screened report; `None` while screening is off).
    screen: Option<RoundScreen>,
}

impl OpenRound {
    /// Resident bytes this round charges against the memory budget.
    fn ram_bytes(&self) -> usize {
        match &self.state {
            AccState::Ram { .. } => 16 * self.spec.d,
            AccState::Spilled { pending_bytes, .. } => *pending_bytes,
        }
    }
}

/// How many closed-round results to keep for late clients before the
/// oldest are evicted.
const FINISHED_CACHE_CAP: usize = 4096;

/// Compact a spilled round (fold its pending frames into the run) once
/// this many frames queue up…
const COMPACT_PENDING_MAX: usize = 8;
/// …or once they hold this many resident bytes.
const COMPACT_PENDING_BYTES: usize = 1 << 20;
/// Per-pending-frame bookkeeping overhead charged to the budget.
const PENDING_OVERHEAD: usize = 16;

/// What [`CohortTable::durable`] found and replayed from a data dir.
#[derive(Clone, Debug, Default)]
pub struct RecoveryReport {
    /// Valid report records folded back into open rounds.
    pub reports_replayed: u64,
    /// Rounds left open (resumed) after replay.
    pub rounds_reopened: usize,
    /// Close records re-applied (their results re-cached for late
    /// clients).
    pub rounds_closed: u64,
    /// Valid WAL bytes after tail validation.
    pub wal_bytes: u64,
    /// Present iff a torn/corrupt WAL tail was truncated away.
    pub tail: Option<TailTruncation>,
    /// Stale run files deleted at open.
    pub stale_runs_removed: usize,
    /// The manifest failed validation and was rebuilt fresh.
    pub manifest_rebuilt: bool,
    /// Replay oddities that were skipped over (duplicate records, a
    /// close for an unknown round) — nonzero is suspicious, not fatal.
    pub warnings: u64,
}

/// The leader-side table of all cohorts' open and recently-closed
/// rounds.
pub struct CohortTable {
    open: HashMap<CohortKey, OpenRound>,
    finished: HashMap<CohortKey, RoundResult>,
    /// FIFO of `finished` keys for bounded-memory eviction.
    finished_order: std::collections::VecDeque<CohortKey>,
    stats: HashMap<u64, CohortStats>,
    /// Durability backend; `None` = plain in-RAM table.
    store: Option<Store>,
    /// Spill threshold over all open accumulators' resident bytes.
    mem_budget: usize,
    /// Suppresses WAL appends and checkpoints while replaying the WAL
    /// (replaying a record must not re-log it).
    replaying: bool,
    /// Storage failures survived so far (each also degraded gracefully:
    /// a rejected report, a kept-in-RAM round, or a lost close marker).
    store_errors: u64,
    /// Report-screening level; `Off` keeps every path bit-identical to
    /// the pre-screening table.
    screen: ScreenMode,
    /// ℓ∞ plausibility slack for [`ScreenMode::Distance`].
    distance_slack: f64,
    /// Admission cap: total open rounds across all cohorts.
    max_open_rounds: usize,
    /// Admission cap: distinct cohorts with at least one open round.
    max_open_cohorts: usize,
    /// Admission cap: resident accumulator bytes (a hard refusal, on
    /// top of `mem_budget`'s soft spill threshold).
    max_resident_bytes: usize,
    /// Backoff hint carried in [`Submit::Shed`].
    retry_after_ms: u64,
    /// High-water mark of resident accumulator bytes (tracked only
    /// while a resident cap or spill budget is configured).
    peak_resident: usize,
}

impl Default for CohortTable {
    fn default() -> Self {
        CohortTable {
            open: HashMap::new(),
            finished: HashMap::new(),
            finished_order: std::collections::VecDeque::new(),
            stats: HashMap::new(),
            store: None,
            // A derived default would be 0 = spill everything.
            mem_budget: usize::MAX,
            replaying: false,
            store_errors: 0,
            screen: ScreenMode::Off,
            distance_slack: DEFAULT_SLACK,
            max_open_rounds: usize::MAX,
            max_open_cohorts: usize::MAX,
            max_resident_bytes: usize::MAX,
            retry_after_ms: 50,
            peak_resident: 0,
        }
    }
}

impl CohortTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// A durable table over `opts.data_dir`: open (or create) the
    /// store, truncate any torn/corrupt WAL tail, and replay the log —
    /// re-folding every accepted report and re-closing every closed
    /// round — so the table resumes exactly where the previous process
    /// stopped. Bit-identical estimates are the contract: a leader
    /// killed mid-round and recovered produces the same renormalized
    /// partial mean as an uninterrupted one.
    pub fn durable(opts: &DurabilityOpts) -> Result<(Self, RecoveryReport), StoreError> {
        let (store, records, info) = Store::open(opts)?;
        let mut table = CohortTable {
            store: Some(store),
            mem_budget: opts.mem_budget,
            replaying: true,
            ..CohortTable::default()
        };
        let mut report = RecoveryReport {
            wal_bytes: info.wal_bytes,
            tail: info.tail,
            stale_runs_removed: info.stale_runs_removed,
            manifest_rebuilt: info.manifest_rebuilt,
            ..RecoveryReport::default()
        };
        for rec in records {
            match rec {
                WalRecord::Report {
                    cohort,
                    round,
                    client,
                    spec,
                    deadline_ms,
                    msg,
                } => {
                    let key = CohortKey { cohort, round };
                    match table.submit(key, &spec, client as usize, &msg, 0, deadline_ms) {
                        Submit::Pending { .. } | Submit::Complete(_) => {
                            report.reports_replayed += 1;
                        }
                        // Shed/Quarantined cannot occur on replay (the
                        // table's screen and caps are still at their
                        // defaults while `durable` runs; the service
                        // configures them afterwards, so the WAL holds
                        // only previously-accepted reports) — counted
                        // as warnings for the same reason duplicates
                        // are.
                        Submit::Late(_)
                        | Submit::Rejected(_)
                        | Submit::Shed { .. }
                        | Submit::Quarantined(_) => report.warnings += 1,
                    }
                }
                WalRecord::Close {
                    cohort,
                    round,
                    received,
                    partial,
                    ..
                } => {
                    let key = CohortKey { cohort, round };
                    if let Some(r) = table.open.get(&key) {
                        if r.received as u32 != received {
                            report.warnings += 1;
                        }
                        match table.close_round(key, partial) {
                            Ok(_) => report.rounds_closed += 1,
                            Err(_) => report.warnings += 1,
                        }
                    } else if !table.finished.contains_key(&key) {
                        report.warnings += 1;
                    }
                }
            }
        }
        table.replaying = false;
        report.rounds_reopened = table.open.len();
        Ok((table, report))
    }

    /// Number of rounds currently accumulating reports.
    pub fn open_rounds(&self) -> usize {
        self.open.len()
    }

    /// Open rounds whose accumulator currently lives in an on-disk run.
    pub fn spilled_rounds(&self) -> usize {
        self.open
            .values()
            .filter(|r| matches!(r.state, AccState::Spilled { .. }))
            .count()
    }

    /// Storage failures survived so far (0 for a plain table).
    pub fn store_errors(&self) -> u64 {
        self.store_errors
    }

    /// Current valid WAL bytes (`None` for a plain table).
    pub fn wal_bytes(&self) -> Option<u64> {
        self.store.as_ref().map(|s| s.wal_len())
    }

    /// Set the report-screening level (default `Off` — bit-identical to
    /// the unscreened table).
    pub fn set_screen(&mut self, mode: ScreenMode) {
        self.screen = mode;
    }

    pub fn screen_mode(&self) -> ScreenMode {
        self.screen
    }

    /// Set the ℓ∞ plausibility slack for [`ScreenMode::Distance`]
    /// (default [`DEFAULT_SLACK`]).
    pub fn set_distance_slack(&mut self, slack: f64) {
        self.distance_slack = slack;
    }

    /// Configure admission-control caps (each defaults to `usize::MAX`
    /// = uncapped). A report that would *open* a round past a cap is
    /// shed; reports into already-open rounds always pass admission.
    pub fn set_limits(
        &mut self,
        max_open_rounds: usize,
        max_open_cohorts: usize,
        max_resident_bytes: usize,
    ) {
        self.max_open_rounds = max_open_rounds;
        self.max_open_cohorts = max_open_cohorts;
        self.max_resident_bytes = max_resident_bytes;
    }

    /// Backoff hint carried in [`Submit::Shed`] (default 50 ms).
    pub fn set_retry_after(&mut self, ms: u64) {
        self.retry_after_ms = ms;
    }

    /// Resident accumulator bytes across all open rounds, right now.
    pub fn resident_bytes(&self) -> usize {
        self.open.values().map(OpenRound::ram_bytes).sum()
    }

    /// High-water mark of [`Self::resident_bytes`], tracked while a
    /// resident cap or spill budget is configured (0 otherwise — the
    /// uncapped table does not pay the O(open) scan per report).
    pub fn peak_resident_bytes(&self) -> usize {
        self.peak_resident
    }

    /// Attribute one service-edge shed (connection cap or rate limit)
    /// to a cohort's ledger, so the health endpoint accounts for every
    /// refused report regardless of which layer refused it.
    pub fn note_shed(&mut self, cohort: u64) {
        let s = self.stats.entry(cohort).or_insert_with(|| CohortStats {
            cohort,
            ..CohortStats::default()
        });
        s.shed += 1;
    }

    /// Record a shed against `cohort` and build the typed refusal.
    fn shed(&mut self, cohort: u64, reason: String) -> Submit {
        self.note_shed(cohort);
        Submit::Shed {
            reason,
            retry_after_ms: self.retry_after_ms,
        }
    }

    /// Fold one client report into its round. `now_ms` is the caller's
    /// monotonic millisecond clock; a *new* round's deadline is set to
    /// `now_ms + deadline_ms` (the first report opens the round).
    pub fn submit(
        &mut self,
        key: CohortKey,
        spec: &CohortSpec,
        client: usize,
        msg: &Message,
        now_ms: u64,
        deadline_ms: u64,
    ) -> Submit {
        if let Some(done) = self.finished.get(&key) {
            return Submit::Late(done.clone());
        }
        if spec.n == 0 || spec.d == 0 {
            return Submit::Rejected("cohort spec must have n >= 1 and d >= 1".into());
        }
        if spec.spec.is_stateful() {
            return Submit::Rejected(format!(
                "stateful codec {} cannot serve stateless cohort reports",
                spec.spec.label()
            ));
        }
        if client >= spec.n {
            return Submit::Rejected(format!(
                "client id {client} out of range for cohort of n={}",
                spec.n
            ));
        }
        // Admission control: a report that would *open* a new round must
        // fit under the caps. Reports into already-open rounds always
        // pass (they grow nothing but a Spilled round's pending queue,
        // which `mem_budget` compaction bounds). Replay is exempt — the
        // WAL's rounds were admitted by the previous process.
        if !self.replaying && !self.open.contains_key(&key) {
            if self.open.len() >= self.max_open_rounds {
                return self.shed(
                    key.cohort,
                    format!("open-round cap {} reached", self.max_open_rounds),
                );
            }
            if self.max_open_cohorts != usize::MAX
                && !self.open.keys().any(|k| k.cohort == key.cohort)
            {
                let distinct: std::collections::HashSet<u64> =
                    self.open.keys().map(|k| k.cohort).collect();
                if distinct.len() >= self.max_open_cohorts {
                    return self.shed(
                        key.cohort,
                        format!("open-cohort cap {} reached", self.max_open_cohorts),
                    );
                }
            }
            if self.max_resident_bytes != usize::MAX
                && self.resident_bytes().saturating_add(16 * spec.d) > self.max_resident_bytes
            {
                return self.shed(
                    key.cohort,
                    format!(
                        "resident accumulator budget {} bytes would be exceeded",
                        self.max_resident_bytes
                    ),
                );
            }
        }
        let round = match self.open.entry(key) {
            Entry::Occupied(e) => {
                let r = e.into_mut();
                if r.spec != *spec {
                    return Submit::Rejected(format!(
                        "spec mismatch: round opened with n={} d={} {}, report carries n={} d={} {}",
                        r.spec.n,
                        r.spec.d,
                        r.spec.spec.label(),
                        spec.n,
                        spec.d,
                        spec.spec.label()
                    ));
                }
                r
            }
            Entry::Vacant(e) => {
                let d = spec.d;
                let s = self.stats.entry(key.cohort).or_insert_with(|| CohortStats {
                    cohort: key.cohort,
                    ..CohortStats::default()
                });
                s.open_rounds += 1;
                e.insert(OpenRound {
                    spec: *spec,
                    state: AccState::Ram {
                        codec: cohort_codec(spec, key.round),
                        zeros: vec![0.0; d],
                        acc: vec![0.0; d],
                    },
                    got: vec![false; spec.n],
                    received: 0,
                    deadline_ms: now_ms.saturating_add(deadline_ms),
                    screen: None,
                })
            }
        };
        if round.got[client] {
            return Submit::Rejected(format!("duplicate report from client {client}"));
        }
        // Screening: validate the report before it touches the WAL or
        // the accumulator, so a screened-out report is bit-invisible.
        // If this report just opened the round, roll the open back —
        // hostile traffic must not pin empty rounds (every open round
        // holds ≥ 1 folded report).
        let mode = self.screen;
        let mut screened: Option<Vec<f64>> = None;
        if mode != ScreenMode::Off {
            if round.screen.is_none() {
                round.screen = Some(RoundScreen::probe(&round.spec, key.round));
            }
            let probe = round.screen.expect("probe just built");
            if let Err(why) = probe.screen_frame(&round.spec, msg) {
                let fresh = round.received == 0;
                if fresh {
                    self.open.remove(&key);
                    let s = self.stats.get_mut(&key.cohort).expect("stats entry exists");
                    s.open_rounds -= 1;
                }
                return self.shed(key.cohort, format!("screened: {why}"));
            }
            let mut z = vec![0.0; round.spec.d];
            match &mut round.state {
                AccState::Ram { codec, zeros, .. } => codec.decode_into(msg, zeros, &mut z),
                AccState::Spilled { .. } => {
                    let codec = cohort_codec(&round.spec, key.round);
                    let zeros = vec![0.0; round.spec.d];
                    codec.decode_into(msg, &zeros, &mut z);
                }
            }
            if let Err(why) = screen_decoded(mode, round.spec.y, self.distance_slack, &z) {
                let fresh = round.received == 0;
                if fresh {
                    self.open.remove(&key);
                }
                let s = self.stats.get_mut(&key.cohort).expect("stats entry exists");
                if fresh {
                    s.open_rounds -= 1;
                }
                s.quarantined += 1;
                return Submit::Quarantined(format!("quarantined: {why}"));
            }
            screened = Some(z);
        }
        // WAL hook: an accepted report hits the log *before* it is
        // folded, so a crash between here and delivery replays it.
        // Replay itself must not re-log what it is reading back.
        // Screened-out reports return above and never reach the log.
        if !self.replaying {
            if let Some(store) = self.store.as_mut() {
                if let Err(e) = store.log_report(key, spec, client as u32, deadline_ms, msg) {
                    self.store_errors += 1;
                    return Submit::Rejected(format!("durability log append failed: {e}"));
                }
            }
        }
        match &mut round.state {
            AccState::Ram { codec, zeros, acc } => match &screened {
                // The `VectorCodec` contract pins the fused fold to be
                // IEEE-op-for-op `decode_into` + `axpy`, and screening
                // already paid for the decode — folding the scratch via
                // `axpy` is bit-identical to the unscreened path.
                Some(z) => crate::linalg::axpy(acc, 1.0, z),
                None => codec.decode_accumulate_into(msg, zeros, 1.0, acc),
            },
            AccState::Spilled {
                pending,
                pending_bytes,
                ..
            } => {
                *pending_bytes += msg.bytes.len() + PENDING_OVERHEAD;
                pending.push(msg.clone());
            }
        }
        round.got[client] = true;
        round.received += 1;
        let received = round.received;
        let expected = round.spec.n;
        let needs_compact = matches!(
            &round.state,
            AccState::Spilled { pending, pending_bytes, .. }
                if pending.len() >= COMPACT_PENDING_MAX
                    || *pending_bytes >= COMPACT_PENDING_BYTES
        );
        // High-water mark for the chaos harness's RSS proxy; only paid
        // for when some resident bound is actually configured.
        if self.max_resident_bytes != usize::MAX || self.mem_budget != usize::MAX {
            self.peak_resident = self.peak_resident.max(self.resident_bytes());
        }
        let stats = self.stats.get_mut(&key.cohort).expect("stats entry exists");
        stats.reports += 1;
        stats.bits_in += msg.bits;
        if received == expected {
            match self.close_round(key, false) {
                Ok(result) => Submit::Complete(result),
                // The round is gone (its run image was unreadable); the
                // caller sees a typed refusal, not a panic.
                Err(e) => Submit::Rejected(format!("round close failed: {e}")),
            }
        } else {
            if needs_compact {
                self.compact_round(key);
            }
            self.maybe_spill();
            Submit::Pending { received, expected }
        }
    }

    /// Close every open round whose deadline has passed, renormalizing
    /// its partial sum over the reports that arrived. Returns the closed
    /// rounds (every open round holds ≥ 1 report — the first report is
    /// what opens it).
    pub fn expire(&mut self, now_ms: u64) -> Vec<(CohortKey, RoundResult)> {
        let mut due: Vec<CohortKey> = self
            .open
            .iter()
            .filter(|(_, r)| r.deadline_ms <= now_ms)
            .map(|(k, _)| *k)
            .collect();
        due.sort_unstable();
        due.into_iter()
            // A round whose run image failed to load is dropped (the
            // failure is already counted in `store_errors`); every
            // other due round still closes.
            .filter_map(|k| self.close_round(k, true).ok().map(|r| (k, r)))
            .collect()
    }

    /// Charge `recipients` estimate deliveries (64·d bits each — the
    /// leader→client leg) to a cohort's ledger. The service calls this
    /// as it actually writes responses, so the meters record what was
    /// transferred, not what was hoped for.
    pub fn note_estimates_sent(&mut self, cohort: u64, d: usize, recipients: usize) {
        if let Some(s) = self.stats.get_mut(&cohort) {
            s.bits_out += 64 * d as u64 * recipients as u64;
        }
    }

    /// Per-cohort accounting, sorted by cohort id. `resident_bytes` is
    /// filled from the open rounds at read time.
    pub fn stats(&self) -> Vec<CohortStats> {
        let mut resident: HashMap<u64, u64> = HashMap::new();
        for (k, r) in &self.open {
            *resident.entry(k.cohort).or_insert(0) += r.ram_bytes() as u64;
        }
        let mut v: Vec<CohortStats> = self.stats.values().copied().collect();
        for s in v.iter_mut() {
            s.resident_bytes = resident.get(&s.cohort).copied().unwrap_or(0);
        }
        v.sort_unstable_by_key(|s| s.cohort);
        v
    }

    /// Aggregate traffic over all cohorts, from the server's seat (in =
    /// received, out = sent).
    pub fn total_traffic(&self) -> Traffic {
        let mut t = Traffic::default();
        for s in self.stats.values() {
            t.recv_bits += s.bits_in;
            t.recv_msgs += s.reports;
            t.sent_bits += s.bits_out;
        }
        t
    }

    fn close_round(
        &mut self,
        key: CohortKey,
        partial_close: bool,
    ) -> Result<RoundResult, StoreError> {
        let mut round = self.open.remove(&key).expect("closing an open round");
        let acc = match &mut round.state {
            AccState::Ram { acc, .. } => std::mem::take(acc),
            AccState::Spilled { seq, pending, .. } => {
                let store = self.store.as_mut().expect("spilled round implies a store");
                let image = match store.load_run(*seq) {
                    Ok(img) => img,
                    Err(e) => {
                        // The fold state is unrecoverable: drop the
                        // round (stats stay consistent) and surface the
                        // typed error to the caller.
                        self.store_errors += 1;
                        let s = self.stats.get_mut(&key.cohort).expect("stats entry exists");
                        s.open_rounds -= 1;
                        return Err(e);
                    }
                };
                let mut acc = image.acc;
                if !pending.is_empty() {
                    // Fold the post-spill arrivals in arrival order — the
                    // same left-to-right addition sequence the RAM path
                    // would have used, so the bits come out identical.
                    let codec = cohort_codec(&round.spec, key.round);
                    let zeros = vec![0.0; round.spec.d];
                    for m in pending.iter() {
                        codec.decode_accumulate_into(m, &zeros, 1.0, &mut acc);
                    }
                }
                let seq = *seq;
                if store.drop_run(seq).is_err() {
                    self.store_errors += 1;
                }
                acc
            }
        };
        let inv_k = 1.0 / round.received.max(1) as f64;
        let result = RoundResult {
            estimate: acc.iter().map(|&a| inv_k * a).collect(),
            received: round.received,
            expected: round.spec.n,
            partial: round.received < round.spec.n,
        };
        // Mark the close in the WAL (best-effort: losing the marker
        // only means replay re-closes the round) and hit the
        // round-granularity fsync point.
        if !self.replaying {
            if let Some(store) = self.store.as_mut() {
                let (r, x) = (result.received as u32, result.expected as u32);
                if store.log_close(key, r, x, result.partial).is_err() {
                    self.store_errors += 1;
                }
                if store.sync_on_close().is_err() {
                    self.store_errors += 1;
                }
            }
        }
        let s = self.stats.get_mut(&key.cohort).expect("stats entry exists");
        s.open_rounds -= 1;
        s.rounds_completed += 1;
        if partial_close && result.partial {
            s.rounds_partial += 1;
        }
        if self.finished.len() >= FINISHED_CACHE_CAP {
            if let Some(old) = self.finished_order.pop_front() {
                self.finished.remove(&old);
            }
        }
        self.finished.insert(key, result.clone());
        self.finished_order.push_back(key);
        // Quiescent point: with no round open, delivered results fully
        // reflect the log — truncate it so restarts replay nothing.
        // (The in-RAM late-client cache does not survive a restart; a
        // late report after one reopens its round, which then closes
        // partial at its deadline.)
        if !self.replaying && self.open.is_empty() {
            if let Some(store) = self.store.as_mut() {
                if store.checkpoint().is_err() {
                    self.store_errors += 1;
                }
            }
        }
        Ok(result)
    }

    /// Spill the largest RAM accumulators to on-disk runs until the
    /// resident total fits the budget (no-op for a plain table).
    fn maybe_spill(&mut self) {
        if self.store.is_none() || self.mem_budget == usize::MAX {
            return;
        }
        loop {
            let total: usize = self.open.values().map(OpenRound::ram_bytes).sum();
            if total <= self.mem_budget {
                return;
            }
            // Largest RAM round first; ties broken toward the smallest
            // key so the spill order is deterministic.
            let candidate = self
                .open
                .iter()
                .filter(|(_, r)| matches!(r.state, AccState::Ram { .. }))
                .max_by(|(ka, ra), (kb, rb)| ra.ram_bytes().cmp(&rb.ram_bytes()).then(kb.cmp(ka)))
                .map(|(k, _)| *k);
            let Some(key) = candidate else { return };
            if !self.spill_round(key) {
                return;
            }
        }
    }

    /// Seal one round's exact accumulator image to a run. Returns false
    /// (round stays in RAM) if the seal fails.
    fn spill_round(&mut self, key: CohortKey) -> bool {
        let round = self.open.get_mut(&key).expect("spilling an open round");
        let AccState::Ram { acc, .. } = &round.state else {
            return false;
        };
        let image = RunImage {
            cohort: key.cohort,
            round: key.round,
            spec: round.spec,
            deadline_ms: round.deadline_ms,
            received: round.received as u32,
            got: round.got.clone(),
            acc: acc.clone(),
        };
        let store = self.store.as_mut().expect("spill requires a store");
        match store.seal_run(&image) {
            Ok(seq) => {
                round.state = AccState::Spilled {
                    seq,
                    pending: Vec::new(),
                    pending_bytes: 0,
                };
                true
            }
            Err(_) => {
                self.store_errors += 1;
                false
            }
        }
    }

    /// LSM-style compaction of one spilled round: load its run, fold
    /// the pending frames in arrival order, seal the new image, drop
    /// the old run. On any failure the pending frames are kept (the
    /// next report retriggers compaction).
    fn compact_round(&mut self, key: CohortKey) {
        let round = self.open.get_mut(&key).expect("compacting an open round");
        let AccState::Spilled { seq, pending, .. } = &mut round.state else {
            return;
        };
        let old_seq = *seq;
        let store = self.store.as_mut().expect("compaction requires a store");
        let mut image = match store.load_run(old_seq) {
            Ok(img) => img,
            Err(_) => {
                self.store_errors += 1;
                return;
            }
        };
        let codec = cohort_codec(&round.spec, key.round);
        let zeros = vec![0.0; round.spec.d];
        for m in pending.iter() {
            codec.decode_accumulate_into(m, &zeros, 1.0, &mut image.acc);
        }
        image.received = round.received as u32;
        image.got = round.got.clone();
        match store.seal_run(&image) {
            Ok(new_seq) => {
                if store.drop_run(old_seq).is_err() {
                    self.store_errors += 1;
                }
                round.state = AccState::Spilled {
                    seq: new_seq,
                    pending: Vec::new(),
                    pending_bytes: 0,
                };
            }
            Err(_) => {
                self.store_errors += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(n: usize, d: usize) -> CohortSpec {
        CohortSpec {
            n,
            d,
            spec: CodecSpec::Lq { q: 64 },
            y: 8.0,
            seed: 42,
        }
    }

    fn encode(cs: &CohortSpec, round: u64, client: usize, x: &[f64]) -> Message {
        let mut codec = cohort_codec(cs, round);
        let mut rng = client_encoder_rng(cs.seed, round, client);
        codec.encode(x, &mut rng)
    }

    /// Reference mean: decode each report against zeros with the shared
    /// codec, sum in submission order, divide by k.
    fn reference_mean(cs: &CohortSpec, round: u64, reports: &[(usize, Message)]) -> Vec<f64> {
        let codec = cohort_codec(cs, round);
        let zeros = vec![0.0; cs.d];
        let mut acc = vec![0.0; cs.d];
        for (_, m) in reports {
            codec.decode_accumulate_into(m, &zeros, 1.0, &mut acc);
        }
        let inv = 1.0 / reports.len() as f64;
        acc.iter().map(|&a| inv * a).collect()
    }

    #[test]
    fn full_round_completes_with_renormalized_mean() {
        let cs = spec(3, 8);
        let key = CohortKey { cohort: 5, round: 0 };
        let inputs: Vec<Vec<f64>> = (0..3).map(|i| vec![1.0 + i as f64; 8]).collect();
        let reports: Vec<(usize, Message)> = inputs
            .iter()
            .enumerate()
            .map(|(c, x)| (c, encode(&cs, 0, c, x)))
            .collect();
        let mut table = CohortTable::new();
        for (c, m) in &reports[..2] {
            match table.submit(key, &cs, *c, m, 0, 1000) {
                Submit::Pending { received, expected } => {
                    assert_eq!((received, expected), (c + 1, 3));
                }
                other => panic!("expected Pending, got {other:?}"),
            }
        }
        let result = match table.submit(key, &cs, 2, &reports[2].1, 0, 1000) {
            Submit::Complete(r) => r,
            other => panic!("expected Complete, got {other:?}"),
        };
        assert_eq!(result.received, 3);
        assert!(!result.partial);
        assert_eq!(result.estimate, reference_mean(&cs, 0, &reports));
        // True mean is 2.0 per coordinate; q=64 at y=8 keeps error small.
        for &v in &result.estimate {
            assert!((v - 2.0).abs() < 0.3, "estimate {v} far from 2.0");
        }
        // Late duplicate gets the cached result back.
        match table.submit(key, &cs, 0, &reports[0].1, 5, 1000) {
            Submit::Late(r) => assert_eq!(r, result),
            other => panic!("expected Late, got {other:?}"),
        }
    }

    #[test]
    fn deadline_expiry_renormalizes_partial_mean_k_of_n() {
        let cs = spec(4, 6);
        let key = CohortKey { cohort: 9, round: 3 };
        // Only clients 0 and 2 of 4 report.
        let xs = [vec![4.0; 6], vec![-2.0; 6]];
        let reports: Vec<(usize, Message)> = [(0usize, &xs[0]), (2usize, &xs[1])]
            .iter()
            .map(|&(c, x)| (c, encode(&cs, 3, c, x)))
            .collect();
        let mut table = CohortTable::new();
        for (c, m) in &reports {
            match table.submit(key, &cs, *c, m, 100, 50) {
                Submit::Pending { .. } => {}
                other => panic!("expected Pending, got {other:?}"),
            }
        }
        assert!(table.expire(149).is_empty(), "deadline not yet reached");
        let closed = table.expire(150);
        assert_eq!(closed.len(), 1);
        let (k, result) = &closed[0];
        assert_eq!(*k, key);
        assert_eq!(result.received, 2);
        assert_eq!(result.expected, 4);
        assert!(result.partial);
        // Renormalized over k=2 arrived reports, not n=4.
        assert_eq!(result.estimate, reference_mean(&cs, 3, &reports));
        for &v in &result.estimate {
            assert!((v - 1.0).abs() < 0.3, "partial mean {v} far from 1.0");
        }
        let stats = table.stats();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].rounds_partial, 1);
        assert_eq!(stats[0].open_rounds, 0);
    }

    #[test]
    fn rejects_bad_reports_without_corrupting_state() {
        let cs = spec(2, 4);
        let key = CohortKey { cohort: 1, round: 0 };
        let m = encode(&cs, 0, 0, &[1.0; 4]);
        let mut table = CohortTable::new();
        // Stateful codec refused.
        let bad = CohortSpec {
            spec: CodecSpec::EfSign,
            ..cs
        };
        assert!(matches!(
            table.submit(key, &bad, 0, &m, 0, 100),
            Submit::Rejected(_)
        ));
        // Client out of range refused.
        assert!(matches!(
            table.submit(key, &cs, 2, &m, 0, 100),
            Submit::Rejected(_)
        ));
        assert!(matches!(
            table.submit(key, &cs, 0, &m, 0, 100),
            Submit::Pending { .. }
        ));
        // Duplicate client refused, round still open with 1 report.
        assert!(matches!(
            table.submit(key, &cs, 0, &m, 0, 100),
            Submit::Rejected(_)
        ));
        // Spec mismatch against the opened round refused.
        let other = CohortSpec { y: 2.0, ..cs };
        assert!(matches!(
            table.submit(key, &other, 1, &m, 0, 100),
            Submit::Rejected(_)
        ));
        assert_eq!(table.open_rounds(), 1);
        let stats = table.stats();
        assert_eq!(stats[0].reports, 1);
    }

    #[test]
    fn many_cohorts_multiplex_independently() {
        let cs = spec(2, 4);
        let mut table = CohortTable::new();
        let mut results = Vec::new();
        for cohort in 0..32u64 {
            let key = CohortKey { cohort, round: 7 };
            let x0 = vec![cohort as f64 * 0.1; 4];
            let x1 = vec![cohort as f64 * 0.3; 4];
            let m0 = encode(&cs, 7, 0, &x0);
            let m1 = encode(&cs, 7, 1, &x1);
            assert!(matches!(
                table.submit(key, &cs, 0, &m0, 0, 100),
                Submit::Pending { .. }
            ));
            match table.submit(key, &cs, 1, &m1, 0, 100) {
                Submit::Complete(r) => results.push((cohort, r)),
                other => panic!("expected Complete, got {other:?}"),
            }
        }
        for (cohort, r) in results {
            let want = cohort as f64 * 0.2;
            for &v in &r.estimate {
                assert!((v - want).abs() < 0.2, "cohort {cohort}: {v} vs {want}");
            }
        }
        assert_eq!(table.open_rounds(), 0);
        assert_eq!(table.stats().len(), 32);
        let t = table.total_traffic();
        assert_eq!(t.recv_msgs, 64);
        assert!(t.recv_bits > 0);
    }

    #[test]
    fn admission_caps_shed_new_rounds_but_not_open_ones() {
        let cs = spec(2, 4);
        let mut table = CohortTable::new();
        table.set_limits(1, usize::MAX, usize::MAX);
        table.set_retry_after(75);
        let key_a = CohortKey { cohort: 1, round: 0 };
        let key_b = CohortKey { cohort: 2, round: 0 };
        let m = encode(&cs, 0, 0, &[1.0; 4]);
        assert!(matches!(
            table.submit(key_a, &cs, 0, &m, 0, 1000),
            Submit::Pending { .. }
        ));
        // A second round would breach the cap: shed with the hint.
        match table.submit(key_b, &cs, 0, &m, 0, 1000) {
            Submit::Shed { retry_after_ms, .. } => assert_eq!(retry_after_ms, 75),
            other => panic!("expected Shed, got {other:?}"),
        }
        // The open round still accepts and completes.
        let m1 = encode(&cs, 0, 1, &[3.0; 4]);
        assert!(matches!(
            table.submit(key_a, &cs, 1, &m1, 0, 1000),
            Submit::Complete(_)
        ));
        let stats = table.stats();
        let shed: u64 = stats.iter().map(|s| s.shed).sum();
        assert_eq!(shed, 1);
        assert_eq!(stats.iter().find(|s| s.cohort == 2).unwrap().shed, 1);
    }

    #[test]
    fn resident_byte_cap_sheds_and_tracks_peak() {
        let cs = spec(2, 8);
        let mut table = CohortTable::new();
        // One 16·8 = 128-byte accumulator fits; a second does not.
        table.set_limits(usize::MAX, usize::MAX, 200);
        let m = encode(&cs, 0, 0, &[1.0; 8]);
        let key_a = CohortKey { cohort: 1, round: 0 };
        let key_b = CohortKey { cohort: 1, round: 1 };
        assert!(matches!(
            table.submit(key_a, &cs, 0, &m, 0, 1000),
            Submit::Pending { .. }
        ));
        assert!(matches!(
            table.submit(key_b, &cs, 0, &m, 0, 1000),
            Submit::Shed { .. }
        ));
        assert_eq!(table.resident_bytes(), 128);
        assert_eq!(table.peak_resident_bytes(), 128);
        assert_eq!(table.stats()[0].resident_bytes, 128);
    }

    #[test]
    fn screened_honest_rounds_are_bit_identical_to_unscreened() {
        let cs = spec(3, 16);
        let key = CohortKey { cohort: 7, round: 2 };
        let reports: Vec<(usize, Message)> = (0..3)
            .map(|c| {
                let x: Vec<f64> = (0..16).map(|i| ((c * 16 + i) as f64 * 0.21).sin() * 5.0).collect();
                (c, encode(&cs, 2, c, &x))
            })
            .collect();
        let mut run = |mode: ScreenMode| {
            let mut table = CohortTable::new();
            table.set_screen(mode);
            let mut out = None;
            for (c, m) in &reports {
                match table.submit(key, &cs, *c, m, 0, 1000) {
                    Submit::Pending { .. } => {}
                    Submit::Complete(r) => out = Some(r),
                    other => panic!("screen={mode:?}: unexpected {other:?}"),
                }
            }
            out.expect("round completed")
        };
        let off = run(ScreenMode::Off);
        let basic = run(ScreenMode::Basic);
        let distance = run(ScreenMode::Distance);
        // Bit-identical estimates — the screened fold is the same IEEE
        // op sequence as the fused one.
        assert_eq!(off, basic);
        assert_eq!(off, distance);
    }

    #[test]
    fn quarantined_report_leaves_round_bit_identical_to_never_arrived() {
        let cs = CohortSpec {
            n: 2,
            d: 4,
            spec: CodecSpec::Full,
            y: 8.0,
            seed: 3,
        };
        let key = CohortKey { cohort: 4, round: 0 };
        let honest: Vec<(usize, Message)> = (0..2)
            .map(|c| (c, encode(&cs, 0, c, &[1.5 + c as f64; 4])))
            .collect();
        // Hostile payloads at the exact probe size: raw f32 fields.
        let craft = |v: f32| {
            let mut bytes = Vec::new();
            for _ in 0..cs.d {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            Message { bits: 32 * cs.d as u64, bytes }
        };
        let mut table = CohortTable::new();
        table.set_screen(ScreenMode::Distance);
        assert!(matches!(
            table.submit(key, &cs, 0, &honest[0].1, 0, 1000),
            Submit::Pending { .. }
        ));
        // NaN payload from client 1: quarantined, round untouched.
        assert!(matches!(
            table.submit(key, &cs, 1, &craft(f32::NAN), 0, 1000),
            Submit::Quarantined(_)
        ));
        // Far-but-finite payload: quarantined by the distance filter.
        assert!(matches!(
            table.submit(key, &cs, 1, &craft(1.0e30), 0, 1000),
            Submit::Quarantined(_)
        ));
        // The honest completion still matches the two-honest reference.
        let result = match table.submit(key, &cs, 1, &honest[1].1, 0, 1000) {
            Submit::Complete(r) => r,
            other => panic!("expected Complete, got {other:?}"),
        };
        assert_eq!(result.estimate, reference_mean(&cs, 0, &honest));
        let s = table.stats()[0];
        assert_eq!((s.reports, s.quarantined, s.shed), (2, 2, 0));
        assert_eq!(s.screen_stats().quarantined, 2);
    }

    #[test]
    fn frame_screen_sheds_truncated_reports_and_rolls_back_fresh_rounds() {
        let cs = spec(2, 8);
        let key = CohortKey { cohort: 9, round: 1 };
        let mut table = CohortTable::new();
        table.set_screen(ScreenMode::Basic);
        let mut bad = encode(&cs, 1, 0, &[2.0; 8]);
        bad.bytes.pop();
        bad.bits = 8 * bad.bytes.len() as u64;
        // A shed first report must not leave an empty open round behind.
        assert!(matches!(
            table.submit(key, &cs, 0, &bad, 0, 1000),
            Submit::Shed { .. }
        ));
        assert_eq!(table.open_rounds(), 0);
        assert_eq!(table.stats()[0].open_rounds, 0);
        assert_eq!(table.stats()[0].shed, 1);
        // Honest traffic afterwards is unaffected.
        let m0 = encode(&cs, 1, 0, &[2.0; 8]);
        let m1 = encode(&cs, 1, 1, &[4.0; 8]);
        assert!(matches!(
            table.submit(key, &cs, 0, &m0, 0, 1000),
            Submit::Pending { .. }
        ));
        assert!(matches!(
            table.submit(key, &cs, 1, &m1, 0, 1000),
            Submit::Complete(_)
        ));
    }
}
