//! Pluggable transport layer and the multi-cohort DME service built on
//! top of it.
//!
//! # From simulator to service
//!
//! The paper's distributed model (Section 1.1) charges a protocol for
//! the *bits sent and received by any machine*. [`crate::sim`] meters
//! that bit-exactly over in-process channels; this module extracts the
//! abstractions that let the identical protocol bodies run over real
//! sockets without touching the cost model:
//!
//! - [`TransportEndpoint`]: one machine's fallible view of the network —
//!   `send`/`recv`/`recv_from`/`broadcast` plus a per-machine [`Traffic`]
//!   snapshot. The in-process [`crate::sim::Endpoint`] is the *reference
//!   implementation*: protocol code generic over this trait is
//!   bit-identical to the hardwired simulator (pinned by
//!   `tests/session_parity.rs` and the loopback parity tests in
//!   `tests/transport.rs`).
//! - [`Transport`]: a factory for the `n` connected endpoints of one
//!   cluster, with cluster-wide traffic readout. Implemented by
//!   [`crate::sim::Cluster`] (channels) and [`tcp::LoopbackMesh`]
//!   (length-prefixed frames over `std::net::TcpStream`).
//!
//! # The service loop and the per-machine bit-cost model
//!
//! [`service`] multiplexes many independent client *cohorts* through one
//! leader process. A cohort is a `(cohort_id, round_id)`-tagged group of
//! `n` reporting clients; each report is one quantized
//! [`crate::quant::Message`], folded into a streaming mean accumulator
//! exactly like the star leader of Algorithm 3 folds its `n − 1` uploads
//! (`decode_accumulate_into`, the same kernel behind
//! [`crate::coordinator::fold_mean`]). The paper's cost accounting maps
//! onto the service as:
//!
//! - **client → leader**: each report costs its metered `msg.bits` — the
//!   encoder's exact bit count, *not* the padded wire bytes. Framing
//!   overhead (the 12-byte `[bits: u64][len: u32]` prefix, headers) is
//!   transport bookkeeping and is excluded from the meters, exactly as
//!   the in-process simulator excludes channel overhead.
//! - **leader → client**: the returned estimate is `d` full-precision
//!   floats, charged at `64·d` bits per recipient — the "leader
//!   broadcasts the result" leg of the star topology.
//! - **partial participation**: when only `k ≤ n` reports arrive by the
//!   cohort's round deadline, the leader renormalizes the partial sum by
//!   `1/k` (graceful degradation; the Chebyshev distance bound still
//!   holds for the clients that did report). The per-machine costs of
//!   the missing clients are simply absent — the meters record what was
//!   actually transferred.
//!
//! Per-cohort [`Traffic`] tallies and a health/stats endpoint expose
//! this accounting live, so "bits per machine per round" — the quantity
//! every theorem in the paper bounds — is observable in the serving
//! path, not only in benchmarks.
//!
//! # Straggler policy: in-round k-of-n mirrors the service semantics
//!
//! The service's partial-participation rule above also runs *inside*
//! session rounds (see the "Straggler policy" section of
//! [`crate::coordinator`]): a [`crate::coordinator::StragglerPolicy`]
//! gives every round a deadline, a minimum quorum `k_min`, and a
//! [`retry::RetrySchedule`] whose jittered backoff windows pace the
//! leader's receive attempts. The two layers are the same semantics at
//! different granularity:
//!
//! - the cohort table's deadline ↔ the policy's per-round `deadline`;
//! - `OpenRound::close`'s `1/k` renormalization over the `k` reports
//!   that arrived ↔ the in-round partial mean over the machines whose
//!   uploads beat the deadline (the identical `inv_k * acc` fold, so a
//!   k-of-n session round and a k-of-n cohort round produce bit-equal
//!   estimates from equal report sets);
//! - the service answering waiters with `partial = true` ↔ the session's
//!   `RoundOutcome` reporting `participants`, `dropped` and
//!   `retries_used`, with `k < k_min` surfacing as the typed
//!   [`TransportError::QuorumFailed`] instead of a panic.
//!
//! Faults to exercise that policy come from [`faulty`]: a seeded
//! [`faulty::FaultPlan`] wraps any endpoint in a
//! [`faulty::FaultyEndpoint`] and injects per-machine per-round drops,
//! delays, duplicates, corruption, crashes and slow starts,
//! reproducibly from one seed.
//!
//! # Durability and the fsync trade-off
//!
//! The layers above survive *network* faults; [`crate::store`] extends
//! the leader to survive its own crash. With
//! [`cohort::CohortTable::durable`] every accepted report is appended to
//! a checksummed write-ahead log (the report's [`frame`]-encoded wire
//! bytes, verbatim, under a `(cohort, round, client)` envelope) before
//! it is folded, accumulators past a memory budget spill to on-disk run
//! files, and a restarted `dme serve --data-dir` replays the log into
//! the exact fold the killed leader was building — same arrival order,
//! same streaming `decode_accumulate_into` arithmetic, bit-identical
//! renormalized partial means (pinned by `rust/tests/durability.rs` and
//! the CI crash-recovery smoke).
//!
//! Durability is deliberately **off the wire**: WAL and run-file bytes
//! move leader-local, so the paper's per-machine communication meters —
//! the quantity its theorems bound — are unchanged by any
//! [`crate::store::SyncPolicy`]. What the policy prices is crash-window
//! risk against fsync stalls on the serving path; the bit-cost ledger
//! next to the paper's model lives in the [`crate::store`] module docs.
//!
//! # Overload & screening
//!
//! The layers above assume every byte arriving at the service edge is
//! honest. A leader for "millions of users" cannot: the edge must
//! survive floods, drip-feeds, and payloads crafted to poison the fold.
//! [`service`] and [`cohort`] harden it in two tiers, both default-off
//! (the unconfigured service is bit-identical to the pre-hardening one):
//!
//! **Admission control and backpressure** bound what load is accepted
//! at all. [`service::ServeOpts`] caps concurrent connections
//! (`max_conns`), open rounds and distinct open cohorts
//! (`max_open_rounds` / `max_open_cohorts`), resident accumulator bytes
//! (`max_resident_bytes` — a hard refusal on top of the durability
//! layer's soft `mem_budget` spill), and per-reporter report rate
//! ([`service::RateLimit`], a token bucket keyed by `(cohort, client)`).
//! Excess load is *shed*, not queued: the server answers a typed
//! `Busy { retry_after_ms }` ([`TransportError::Overloaded`] on the
//! client) and stays responsive for admitted rounds, and the client
//! entry points honor the hint through the shared
//! [`retry::RetrySchedule`] backoff. A per-connection lifetime deadline
//! (`conn_deadline`, on top of the per-read `read_timeout`) defeats
//! slow-loris clients that keep individual reads alive forever.
//!
//! **Report screening** ([`screen`]) validates what admission lets in,
//! *before* the WAL append and the fold — a screened-out report is
//! bit-invisible to estimates, meters and the durability log. The
//! `screen=off|basic|distance` knob selects: size coherence against a
//! per-round zero-vector probe (every stateless codec's message size is
//! input-independent, so a mismatch proves malformation — and keeps
//! truncated bit streams away from the panic-on-overrun bit readers),
//! float hygiene on the decoded vector (NaN/Inf never reach an
//! accumulator), and the paper-grounded distance filter. The last is
//! the point where the paper's geometry pays off operationally: because
//! the error bounds depend on the *distance between inputs* rather than
//! their norms (PAPER.md, Theorem 1.1 vs. the norm-bounded baselines),
//! the cohort's `y` — an ℓ∞ bound on client vectors, decode reference
//! zero — makes any decoded report with `‖z‖∞ > slack · y` implausible
//! for *every* in-spec input, independent of what the other clients
//! sent. Such reports are *quarantined*: dropped from the fold but
//! tallied per cohort ([`cohort::CohortStats`]'s `shed`/`quarantined`,
//! surfaced by the health endpoint) so the operator sees the attack
//! instead of a silently-corrupted mean. The seeded chaos harness
//! (`dme exp chaos`, `crate::exp::workload`) drives all of the above
//! against a live server and asserts honest rounds still close with
//! exact renormalized means.

use crate::quant::Message;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

pub mod cohort;
pub mod error;
pub mod faulty;
pub mod frame;
pub mod retry;
pub mod screen;
pub mod service;
pub mod tcp;
pub mod wire;

pub use error::{FrameError, TransportError};

/// A routed packet: who sent it, and the metered message.
#[derive(Debug)]
pub struct Packet {
    pub from: usize,
    pub msg: Message,
}

/// Shared per-machine traffic counters (atomics: the senders, receivers
/// and reporting threads all touch them concurrently).
#[derive(Debug, Default)]
pub struct Meter {
    pub sent_bits: AtomicU64,
    pub recv_bits: AtomicU64,
    pub sent_msgs: AtomicU64,
    pub recv_msgs: AtomicU64,
}

impl Meter {
    /// Record an outgoing message of `bits` metered bits.
    pub fn note_sent(&self, bits: u64) {
        self.sent_bits.fetch_add(bits, Ordering::Relaxed);
        self.sent_msgs.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an incoming message of `bits` metered bits.
    pub fn note_recv(&self, bits: u64) {
        self.recv_bits.fetch_add(bits, Ordering::Relaxed);
        self.recv_msgs.fetch_add(1, Ordering::Relaxed);
    }

    /// Consistent point-in-time snapshot for reporting.
    pub fn snapshot(&self) -> Traffic {
        Traffic {
            sent_bits: self.sent_bits.load(Ordering::Relaxed),
            recv_bits: self.recv_bits.load(Ordering::Relaxed),
            sent_msgs: self.sent_msgs.load(Ordering::Relaxed),
            recv_msgs: self.recv_msgs.load(Ordering::Relaxed),
        }
    }
}

/// Traffic snapshot for reporting.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Traffic {
    pub sent_bits: u64,
    pub recv_bits: u64,
    pub sent_msgs: u64,
    pub recv_msgs: u64,
}

impl Traffic {
    pub fn total_bits(&self) -> u64 {
        self.sent_bits + self.recv_bits
    }

    /// Add another snapshot's counts into this one (the batch round
    /// plane prefix-sums per-slot tallies into cumulative snapshots).
    pub fn accumulate(&mut self, other: &Traffic) {
        self.sent_bits += other.sent_bits;
        self.recv_bits += other.recv_bits;
        self.sent_msgs += other.sent_msgs;
        self.recv_msgs += other.recv_msgs;
    }
}

/// Summary statistics over per-machine traffic (the paper reports the
/// worst machine and the mean).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TrafficSummary {
    pub max_sent: u64,
    pub max_recv: u64,
    pub mean_sent: f64,
    pub mean_recv: f64,
    pub max_total: u64,
}

pub fn summarize(traffic: &[Traffic]) -> TrafficSummary {
    let n = traffic.len().max(1) as f64;
    TrafficSummary {
        max_sent: traffic.iter().map(|t| t.sent_bits).max().unwrap_or(0),
        max_recv: traffic.iter().map(|t| t.recv_bits).max().unwrap_or(0),
        mean_sent: traffic.iter().map(|t| t.sent_bits).sum::<u64>() as f64 / n,
        mean_recv: traffic.iter().map(|t| t.recv_bits).sum::<u64>() as f64 / n,
        max_total: traffic.iter().map(|t| t.total_bits()).max().unwrap_or(0),
    }
}

/// One machine's fallible view of the cluster network.
///
/// The contract every implementation must honor (and that the
/// in-process reference pins bit-exactly):
///
/// - **Metering**: `send` charges the local machine `msg.bits` sent bits
///   and one sent message *before* attempting delivery; a delivered
///   packet charges the receiver `msg.bits` received bits and one
///   received message no later than when `recv`/`recv_from` returns it.
///   After a completed exchange the per-machine totals are therefore
///   transport-independent.
/// - **Ordering**: packets from one sender arrive in send order
///   (per-peer FIFO). `recv_from(p)` returns the oldest undelivered
///   packet from `p`, stashing — never dropping — packets from other
///   peers; `recv()` returns the oldest stashed packet first (global
///   arrival order), then blocks on the network.
/// - **Errors**: operations return [`TransportError`] instead of
///   panicking; a peer disappearing mid-protocol is `PeerClosed`, the
///   whole cluster going away is `Shutdown`.
pub trait TransportEndpoint {
    /// This machine's id in `0..n`.
    fn id(&self) -> usize;

    /// Cluster size.
    fn n(&self) -> usize;

    /// Send `msg` to machine `to`, metering the local side.
    fn send(&mut self, to: usize, msg: Message) -> Result<(), TransportError>;

    /// Blocking receive of the next packet from anyone (oldest stashed
    /// packet first).
    fn recv(&mut self) -> Result<Packet, TransportError>;

    /// Blocking receive of the next packet from the specific peer
    /// `from`; packets from other peers are stashed in per-peer FIFO
    /// order for later delivery.
    fn recv_from(&mut self, from: usize) -> Result<Packet, TransportError>;

    /// Like [`TransportEndpoint::recv`], but gives up with
    /// [`TransportError::Timeout`] after `timeout`.
    fn recv_timeout(&mut self, timeout: Duration) -> Result<Packet, TransportError>;

    /// Send the same message to every other machine.
    fn broadcast(&mut self, msg: &Message) -> Result<(), TransportError> {
        for to in 0..self.n() {
            if to != self.id() {
                self.send(to, msg.clone())?;
            }
        }
        Ok(())
    }

    /// Snapshot of this machine's traffic counters.
    fn traffic(&self) -> Traffic;
}

/// A factory for the `n` connected endpoints of one cluster.
pub trait Transport {
    type Endpoint: TransportEndpoint + Send;

    /// Cluster size.
    fn n(&self) -> usize;

    /// Build (or hand out) the `n` endpoints, in machine order. May be
    /// called once; implementations may fail on reconnection attempts.
    fn open(&mut self) -> Result<Vec<Self::Endpoint>, TransportError>;

    /// Per-machine traffic snapshot, in machine order.
    fn traffic(&self) -> Vec<Traffic>;
}

/// Per-peer FIFO stash of out-of-order packets.
///
/// `recv_from(p)` while a packet from `q ≠ p` is in flight must park the
/// `q` packet for later. The old implementation kept one flat `Vec` and
/// rescanned it linearly per delivery — O(stash²) across a round when a
/// slow peer backs everything up. This keeps one `VecDeque` per sender
/// (O(1) push and pop) plus a global arrival sequence so `recv()` can
/// still hand back the *earliest* stashed packet across all peers.
#[derive(Debug)]
pub struct Stash {
    queues: Vec<VecDeque<(u64, Packet)>>,
    next_seq: u64,
    len: usize,
}

impl Stash {
    pub fn new(n: usize) -> Self {
        Stash {
            queues: (0..n).map(|_| VecDeque::new()).collect(),
            next_seq: 0,
            len: 0,
        }
    }

    /// Number of stashed packets.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Park a packet, preserving arrival order. O(1).
    pub fn push(&mut self, p: Packet) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.queues[p.from].push_back((seq, p));
        self.len += 1;
    }

    /// Oldest stashed packet from `from`, if any. O(1).
    pub fn pop_from(&mut self, from: usize) -> Option<Packet> {
        let (_, p) = self.queues[from].pop_front()?;
        self.len -= 1;
        Some(p)
    }

    /// Oldest stashed packet across all peers (global arrival order), if
    /// any. O(n) over peers, but only when packets are actually stashed.
    pub fn pop_earliest(&mut self) -> Option<Packet> {
        if self.len == 0 {
            return None;
        }
        let from = self
            .queues
            .iter()
            .enumerate()
            .filter_map(|(i, q)| q.front().map(|(seq, _)| (*seq, i)))
            .min()
            .map(|(_, i)| i)?;
        self.pop_from(from)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pkt(from: usize, bits: u64) -> Packet {
        Packet {
            from,
            msg: Message {
                bytes: vec![0u8; (bits as usize + 7) / 8],
                bits,
            },
        }
    }

    #[test]
    fn stash_is_fifo_per_peer_and_earliest_first_globally() {
        let mut s = Stash::new(3);
        s.push(pkt(1, 10));
        s.push(pkt(2, 20));
        s.push(pkt(1, 11));
        assert_eq!(s.len(), 3);
        // Per-peer FIFO.
        assert_eq!(s.pop_from(1).unwrap().msg.bits, 10);
        // Global arrival order: the peer-2 packet arrived before the
        // second peer-1 packet.
        assert_eq!(s.pop_earliest().unwrap().msg.bits, 20);
        assert_eq!(s.pop_earliest().unwrap().msg.bits, 11);
        assert!(s.pop_earliest().is_none());
        assert!(s.is_empty());
    }

    #[test]
    fn meter_snapshot_counts() {
        let m = Meter::default();
        m.note_sent(100);
        m.note_sent(28);
        m.note_recv(7);
        let t = m.snapshot();
        assert_eq!(t.sent_bits, 128);
        assert_eq!(t.sent_msgs, 2);
        assert_eq!(t.recv_bits, 7);
        assert_eq!(t.recv_msgs, 1);
        assert_eq!(t.total_bits(), 135);
    }
}
