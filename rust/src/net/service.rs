//! The multi-cohort DME service: one leader process folding the
//! quantized reports of many independent client cohorts.
//!
//! Server side, [`serve`] runs an accept loop over a caller-bound
//! `TcpListener`: every connection carries one [`super::wire::Request`]
//! and gets one [`super::wire::Response`] (the one-round-trip shape of a
//! star round — the client *is* a star worker, the service *is* the
//! leader). Reports are folded through the [`super::cohort::CohortTable`]
//! streaming accumulator; a report that completes its round answers
//! everyone still parked on that round. Deadline sweeping runs on
//! *every* path that takes the state lock: the accept loop sweeps each
//! iteration (idle ticks included), and every connection handler sweeps
//! before dispatching its request — so under sustained accept traffic,
//! where handler threads dominate the lock, overdue rounds are still
//! expired and their waiters answered with the `1/k`-renormalized
//! partial mean instead of waiting for the accept thread to win the
//! lock.
//!
//! Client side, [`report_round`] encodes one vector under the cohort
//! codec convention (see [`super::cohort`]) and blocks for the round's
//! estimate; [`fetch_stats`] and [`request_shutdown`] drive the health
//! and shutdown endpoints. The `dme serve` / `dme report` CLI
//! subcommands are thin wrappers over these.
//!
//! Bit accounting follows the paper's per-machine model (see the `net`
//! module docs): each accepted report charges its metered `msg.bits`
//! inbound, each estimate delivery charges `64·d` outbound, framing is
//! excluded.
//!
//! The service edge is overload-hardened (see the `net` module docs'
//! "Overload & screening" section): connection, round, cohort and
//! resident-byte caps plus per-reporter token-bucket rate limiting shed
//! excess load with a typed [`Response::Busy`] carrying a backoff hint,
//! a per-connection lifetime deadline defeats drip-feeding (slow-loris)
//! clients, and reports pass the [`super::screen`] validation pass
//! before they touch the WAL or an accumulator. The clients honor
//! `Busy` through the shared [`super::retry::RetrySchedule`].

use super::cohort::{
    client_encoder_rng, cohort_codec, CohortKey, CohortSpec, CohortStats, CohortTable, RoundResult,
    Submit,
};
use super::error::TransportError;
use super::retry::RetrySchedule;
use super::screen::{ScreenMode, DEFAULT_SLACK};
use super::wire::{read_request, read_response, write_request, write_response, Request, Response};
use super::Traffic;
use crate::rng::hash2;
use crate::store::DurabilityOpts;
use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Per-reporter token-bucket rate limit, keyed by `(cohort, client)`.
/// A reporter may burst `burst` reports, then refills at `per_sec`
/// tokens per second; a report with no token is shed with
/// [`Response::Busy`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RateLimit {
    pub burst: f64,
    pub per_sec: f64,
}

/// Server knobs. `Default` is sized for tests and the CI smoke run;
/// long-running deployments mostly raise `max_rounds` to `None`.
/// Every overload knob defaults to "unbounded / off", keeping the
/// default service bit-identical to the pre-hardening one.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Round deadline applied when a report carries `deadline_ms == 0`.
    pub default_deadline_ms: u64,
    /// Exit the accept loop after this many completed rounds
    /// (`None` = run until a shutdown request).
    pub max_rounds: Option<u64>,
    /// Per-connection *single-read* timeout — a silent client cannot
    /// park a handler thread forever on one read.
    pub read_timeout: Duration,
    /// Per-connection *total-lifetime* deadline for reading a request —
    /// a drip-feeding (slow-loris) client that keeps each individual
    /// read alive is still cut off once its connection is this old.
    pub conn_deadline: Duration,
    /// When set, the table is durable: reports are WAL'd before the
    /// fold, accumulators spill past the memory budget, and [`serve`]
    /// recovers open rounds from the data dir on startup (see
    /// [`crate::store`]).
    pub durability: Option<DurabilityOpts>,
    /// Report-screening level for the table (see [`super::screen`]).
    pub screen: ScreenMode,
    /// ℓ∞ plausibility slack for [`ScreenMode::Distance`].
    pub distance_slack: f64,
    /// Admission cap: concurrent connection-handler threads. Excess
    /// connections are answered [`Response::Busy`] from the accept loop.
    pub max_conns: usize,
    /// Admission cap: total open rounds (see [`CohortTable::set_limits`]).
    pub max_open_rounds: usize,
    /// Admission cap: distinct cohorts with open rounds.
    pub max_open_cohorts: usize,
    /// Admission cap: resident accumulator bytes (hard refusal, on top
    /// of the durability layer's soft spill budget).
    pub max_resident_bytes: usize,
    /// Per-reporter token-bucket rate limit (`None` = off).
    pub rate_limit: Option<RateLimit>,
    /// Backoff hint carried in every [`Response::Busy`].
    pub retry_after_ms: u64,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            default_deadline_ms: 2_000,
            max_rounds: None,
            read_timeout: Duration::from_secs(10),
            conn_deadline: Duration::from_secs(30),
            durability: None,
            screen: ScreenMode::Off,
            distance_slack: DEFAULT_SLACK,
            max_conns: usize::MAX,
            max_open_rounds: usize::MAX,
            max_open_cohorts: usize::MAX,
            max_resident_bytes: usize::MAX,
            rate_limit: None,
            retry_after_ms: 50,
        }
    }
}

/// What one [`serve`] run did, for logs and tests.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServeSummary {
    /// Rounds closed (full and partial).
    pub rounds_completed: u64,
    /// Rounds closed at their deadline with k < n reports.
    pub rounds_partial: u64,
    /// Distinct cohorts seen.
    pub cohorts: usize,
    /// Aggregate traffic from the server's seat (recv = reports in,
    /// sent = estimates out), paper units.
    pub traffic: Traffic,
    /// Requests shed under overload: connection cap, rate limit,
    /// admission caps and the pre-decode frame screen combined.
    pub shed: u64,
    /// Reports screened out after decoding (NaN/Inf or the distance
    /// filter).
    pub quarantined: u64,
    /// High-water mark of resident accumulator bytes (0 unless a
    /// resident cap or spill budget was configured — the RSS proxy the
    /// chaos harness asserts against).
    pub peak_resident_bytes: usize,
}

/// One reporter's token bucket (see [`RateLimit`]).
struct TokenBucket {
    tokens: f64,
    last_ms: u64,
}

/// Bound on tracked reporter buckets; past it the map is reset rather
/// than letting an adversary with unbounded `(cohort, client)` ids grow
/// it without limit (a reset only forgives, never blocks, honest
/// clients).
const MAX_BUCKETS: usize = 65_536;

struct State {
    table: super::cohort::CohortTable,
    /// Connections parked until their `(cohort, round)` closes.
    waiters: HashMap<CohortKey, Vec<TcpStream>>,
    /// Per-reporter token buckets, keyed by `(cohort, client)`.
    buckets: HashMap<(u64, u32), TokenBucket>,
    rounds_completed: u64,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    start: Instant,
    opts: ServeOpts,
    /// Requests shed at the accept loop (connection cap) — counted
    /// outside the state lock.
    conn_shed: AtomicU64,
}

impl Shared {
    fn now_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }
}

/// Refill-and-take on one reporter's token bucket. `true` = admitted.
fn take_token(state: &mut State, rl: &RateLimit, key: (u64, u32), now_ms: u64) -> bool {
    if state.buckets.len() > MAX_BUCKETS {
        state.buckets.clear();
    }
    let b = state.buckets.entry(key).or_insert(TokenBucket {
        tokens: rl.burst,
        last_ms: now_ms,
    });
    let elapsed_ms = now_ms.saturating_sub(b.last_ms) as f64;
    b.tokens = (b.tokens + elapsed_ms * rl.per_sec / 1000.0).min(rl.burst);
    b.last_ms = now_ms;
    if b.tokens >= 1.0 {
        b.tokens -= 1.0;
        true
    } else {
        false
    }
}

/// A `Read` that enforces the per-connection lifetime deadline: each
/// read re-checks the wall deadline and bounds the socket timeout by
/// both the remaining lifetime and the per-read slice, so a client
/// dripping one byte per slice cannot hold a handler past
/// [`ServeOpts::conn_deadline`].
struct DeadlineReader<'a> {
    stream: &'a TcpStream,
    deadline: Instant,
    slice: Duration,
}

impl Read for DeadlineReader<'_> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let now = Instant::now();
        if now >= self.deadline {
            return Err(io::Error::new(
                io::ErrorKind::TimedOut,
                "connection lifetime deadline exceeded",
            ));
        }
        let budget = (self.deadline - now).min(self.slice).max(Duration::from_millis(1));
        let _ = self.stream.set_read_timeout(Some(budget));
        Read::read(&mut self.stream, buf)
    }
}

/// Write a round's estimate to one stream, best-effort (a waiter that
/// hung up is simply skipped; the round result is unaffected).
fn send_estimate(stream: &mut TcpStream, r: &RoundResult) -> bool {
    let resp = Response::Estimate {
        received: r.received as u32,
        expected: r.expected as u32,
        partial: r.partial,
        estimate: r.estimate.clone(),
    };
    write_response(stream, &resp).and_then(|()| stream.flush()).is_ok()
}

/// Answer everyone parked on `key` (plus `also`, the report that closed
/// the round, if any) and charge the outbound estimate bits for the
/// deliveries that succeeded. Returns delivered-count.
fn deliver_round(
    state: &mut State,
    key: CohortKey,
    r: &RoundResult,
    also: Option<&mut TcpStream>,
) -> usize {
    let d = r.estimate.len();
    let mut delivered = 0;
    if let Some(stream) = also {
        if send_estimate(stream, r) {
            delivered += 1;
        }
    }
    if let Some(parked) = state.waiters.remove(&key) {
        for mut s in parked {
            if send_estimate(&mut s, r) {
                delivered += 1;
            }
        }
    }
    state.table.note_estimates_sent(key.cohort, d, delivered);
    delivered
}

/// Close every overdue round (all of them, at shutdown) and answer the
/// parked waiters with the renormalized partial means.
fn sweep(shared: &Shared, state: &mut State, force_all: bool) {
    let now = if force_all { u64::MAX } else { shared.now_ms() };
    for (key, r) in state.table.expire(now) {
        state.rounds_completed += 1;
        deliver_round(state, key, &r, None);
    }
    if let Some(cap) = shared.opts.max_rounds {
        if state.rounds_completed >= cap {
            state.shutdown = true;
        }
    }
}

/// Handle one connection: one request, at most one response. A report
/// whose round is still pending parks the stream in the waiter table
/// and returns — the closing report or the deadline sweeper answers it.
fn handle_connection(shared: &Arc<Shared>, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    // The request is read through the lifetime-deadline reader: each
    // individual read is bounded by `read_timeout`, the whole request
    // by `conn_deadline` — a slow-loris client is dropped either way.
    let mut reader = DeadlineReader {
        stream: &stream,
        deadline: Instant::now() + shared.opts.conn_deadline,
        slice: shared.opts.read_timeout,
    };
    let req = match read_request(&mut reader) {
        Ok(req) => req,
        Err(e) => {
            let _ = write_response(&mut stream, &Response::Error(e.to_string()));
            return;
        }
    };
    let _ = stream.set_read_timeout(Some(shared.opts.read_timeout));
    let mut state = shared.state.lock().expect("service state lock");
    // Sweep overdue rounds on the handling path too: with many handler
    // threads contending for the lock, the accept loop's sweep can be
    // starved indefinitely, and a parked waiter must not outlive its
    // round's deadline just because the service is busy. This also
    // guarantees a report racing its own deadline observes the expiry
    // (and is answered `Late`) rather than reopening a closed round.
    sweep(shared, &mut state, false);
    match req {
        Request::Report {
            cohort,
            round,
            client,
            spec,
            deadline_ms,
            msg,
        } => {
            if state.shutdown {
                drop(state);
                let reason = "service shutting down".to_string();
                let _ = write_response(&mut stream, &Response::Error(reason));
                return;
            }
            let key = CohortKey { cohort, round };
            let deadline = if deadline_ms == 0 {
                shared.opts.default_deadline_ms
            } else {
                u64::from(deadline_ms)
            };
            let now = shared.now_ms();
            // Per-reporter rate limiting, ahead of the table: a flooding
            // reporter is shed before it costs a WAL append or a decode.
            if let Some(rl) = &shared.opts.rate_limit {
                if !take_token(&mut state, rl, (cohort, client), now) {
                    state.table.note_shed(cohort);
                    let retry_after_ms = shared.opts.retry_after_ms;
                    drop(state);
                    let _ = write_response(&mut stream, &Response::Busy { retry_after_ms });
                    return;
                }
            }
            match state.table.submit(key, &spec, client as usize, &msg, now, deadline) {
                Submit::Pending { .. } => {
                    // Park; the stream is answered when the round closes.
                    state.waiters.entry(key).or_default().push(stream);
                }
                Submit::Complete(r) => {
                    state.rounds_completed += 1;
                    deliver_round(&mut state, key, &r, Some(&mut stream));
                    if let Some(cap) = shared.opts.max_rounds {
                        if state.rounds_completed >= cap {
                            state.shutdown = true;
                        }
                    }
                }
                Submit::Late(r) => {
                    if send_estimate(&mut stream, &r) {
                        state.table.note_estimates_sent(key.cohort, r.estimate.len(), 1);
                    }
                }
                Submit::Rejected(reason) => {
                    drop(state);
                    let _ = write_response(&mut stream, &Response::Error(reason));
                }
                Submit::Shed { retry_after_ms, .. } => {
                    // Already tallied in the cohort's ledger by the table.
                    drop(state);
                    let _ = write_response(&mut stream, &Response::Busy { retry_after_ms });
                }
                Submit::Quarantined(reason) => {
                    // Not retryable — the payload itself is implausible.
                    drop(state);
                    let _ = write_response(&mut stream, &Response::Error(reason));
                }
            }
        }
        Request::Health => {
            let stats = state.table.stats();
            drop(state);
            let _ = write_response(&mut stream, &Response::Stats(stats));
        }
        Request::Shutdown => {
            state.shutdown = true;
            drop(state);
            let _ = write_response(&mut stream, &Response::Ok);
        }
    }
}

/// Run the service over a caller-bound listener until `max_rounds`
/// rounds complete or a shutdown request arrives. The accept loop polls
/// (nonblocking accept + short sleep) so it doubles as the deadline
/// sweeper without a dedicated timer thread; at exit every still-open
/// round is force-closed and its waiters receive their partial means.
pub fn serve(listener: TcpListener, opts: ServeOpts) -> Result<ServeSummary, TransportError> {
    let table = match &opts.durability {
        // Recovery happens here, before the first accept: a killed
        // leader restarted over the same data dir replays its WAL and
        // resumes every open cohort round exactly where it stopped.
        Some(d) => CohortTable::durable(d).map(|(t, _)| t)?,
        None => CohortTable::new(),
    };
    serve_with_table(listener, opts, table)
}

/// [`serve`] over a caller-built table — the seam the CLI uses to print
/// its recovery report before the accept loop starts, and tests use to
/// pre-load state.
pub fn serve_with_table(
    listener: TcpListener,
    opts: ServeOpts,
    mut table: CohortTable,
) -> Result<ServeSummary, TransportError> {
    listener
        .set_nonblocking(true)
        .map_err(|e| TransportError::from_io(&e))?;
    // Screening and admission knobs are applied here, *after* any
    // durable recovery replayed the WAL — the log holds only reports a
    // previous process already accepted, so replay must stay unscreened
    // and uncapped for bit-identical recovery.
    table.set_screen(opts.screen);
    table.set_distance_slack(opts.distance_slack);
    table.set_limits(opts.max_open_rounds, opts.max_open_cohorts, opts.max_resident_bytes);
    table.set_retry_after(opts.retry_after_ms);
    let shared = Arc::new(Shared {
        state: Mutex::new(State {
            table,
            waiters: HashMap::new(),
            buckets: HashMap::new(),
            rounds_completed: 0,
            shutdown: false,
        }),
        start: Instant::now(),
        opts,
        conn_shed: AtomicU64::new(0),
    });
    let active_conns = Arc::new(AtomicUsize::new(0));
    let mut handles: Vec<thread::JoinHandle<()>> = Vec::new();
    loop {
        match listener.accept() {
            Ok((mut stream, _)) => {
                let _ = stream.set_nonblocking(false);
                if active_conns.load(Ordering::SeqCst) >= shared.opts.max_conns {
                    // Connection cap: shed inline with a bounded write;
                    // never spawn a handler the cap was meant to prevent.
                    shared.conn_shed.fetch_add(1, Ordering::SeqCst);
                    let _ = stream.set_write_timeout(Some(Duration::from_millis(250)));
                    let retry_after_ms = shared.opts.retry_after_ms;
                    let _ = write_response(&mut stream, &Response::Busy { retry_after_ms });
                    continue;
                }
                active_conns.fetch_add(1, Ordering::SeqCst);
                let sh = Arc::clone(&shared);
                let active = Arc::clone(&active_conns);
                handles.push(
                    thread::Builder::new()
                        .name("dme-serve-conn".into())
                        .spawn(move || {
                            // Decrement on every exit path, panics included,
                            // or the connection cap would leak closed slots.
                            struct Slot(Arc<AtomicUsize>);
                            impl Drop for Slot {
                                fn drop(&mut self) {
                                    self.0.fetch_sub(1, Ordering::SeqCst);
                                }
                            }
                            let _slot = Slot(active);
                            handle_connection(&sh, stream)
                        })
                        .expect("spawn connection handler"),
                );
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(2));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(TransportError::from_io(&e)),
        }
        // Reap finished handler threads so a long-running service does
        // not accumulate one JoinHandle per connection ever accepted.
        handles.retain(|h| !h.is_finished());
        let mut state = shared.state.lock().expect("service state lock");
        sweep(&shared, &mut state, false);
        if state.shutdown {
            // Answer every still-open round with its partial mean
            // before tearing the process down.
            sweep(&shared, &mut state, true);
            break;
        }
        drop(state);
    }
    drop(listener);
    for h in handles {
        let _ = h.join();
    }
    let state = shared.state.lock().expect("service state lock");
    let stats = state.table.stats();
    Ok(ServeSummary {
        rounds_completed: state.rounds_completed,
        rounds_partial: stats.iter().map(|s| s.rounds_partial).sum(),
        cohorts: stats.len(),
        traffic: state.table.total_traffic(),
        shed: stats.iter().map(|s| s.shed).sum::<u64>()
            + shared.conn_shed.load(Ordering::SeqCst),
        quarantined: stats.iter().map(|s| s.quarantined).sum(),
        peak_resident_bytes: state.table.peak_resident_bytes(),
    })
}

/// A client's view of a closed round.
#[derive(Clone, Debug, PartialEq)]
pub struct EstimateOut {
    pub estimate: Vec<f64>,
    pub received: usize,
    pub expected: usize,
    pub partial: bool,
}

fn connect(addr: &str, timeout: Duration) -> Result<TcpStream, TransportError> {
    let stream = TcpStream::connect(addr).map_err(|e| TransportError::Connect {
        addr: addr.to_string(),
        attempts: 1,
        last: e.to_string(),
    })?;
    stream
        .set_read_timeout(Some(timeout))
        .map_err(|e| TransportError::from_io(&e))?;
    let _ = stream.set_nodelay(true);
    Ok(stream)
}

/// Run `op` under the shared retry schedule, retrying the transient
/// failure classes: dial failures always, [`TransportError::Overloaded`]
/// always (sleeping at least the server's `retry_after_ms` hint), and
/// established-stream I/O / timeout failures only when `retry_io` —
/// idempotent requests (health, shutdown) set it; a report does not,
/// because a retry after the request bytes left could land as a
/// duplicate of a report the server already folded.
fn retry_transient<T>(
    schedule: &RetrySchedule,
    salt: u64,
    retry_io: bool,
    mut op: impl FnMut() -> Result<T, TransportError>,
) -> Result<T, TransportError> {
    let mut windows = schedule.windows(salt);
    let mut attempt = 1;
    loop {
        match op() {
            Ok(v) => return Ok(v),
            Err(e) => {
                let transient = match &e {
                    TransportError::Connect { .. } | TransportError::Overloaded { .. } => true,
                    TransportError::Io { .. } | TransportError::Timeout { .. } => retry_io,
                    _ => false,
                };
                if !transient || attempt >= schedule.attempts() {
                    return Err(e);
                }
                attempt += 1;
                let mut delay = windows.next().unwrap_or(schedule.backoff_base);
                if let TransportError::Overloaded { retry_after_ms } = &e {
                    delay = delay.max(Duration::from_millis(*retry_after_ms));
                }
                thread::sleep(delay);
            }
        }
    }
}

/// Encode `input` under the cohort codec convention and report it for
/// `(cohort, round)`, blocking until the round closes (all `n` reports
/// in, or the deadline with `k ≤ n`) and returning the round's
/// estimate. `deadline_ms == 0` defers to the server's default.
#[allow(clippy::too_many_arguments)]
pub fn report_round(
    addr: &str,
    cohort: u64,
    round: u64,
    client: usize,
    spec: &CohortSpec,
    input: &[f64],
    deadline_ms: u32,
    timeout: Duration,
) -> Result<EstimateOut, TransportError> {
    assert_eq!(input.len(), spec.d, "input dimension must match the cohort spec");
    let mut codec = cohort_codec(spec, round);
    let mut rng = client_encoder_rng(spec.seed, round, client);
    let msg = codec.encode(input, &mut rng);
    // Retries dial failures and Busy sheds; NOT mid-stream I/O errors —
    // a report is not idempotent once its bytes may have landed.
    let salt = hash2(hash2(cohort, round), client as u64);
    retry_transient(&RetrySchedule::default(), salt, false, || {
        let mut stream = connect(addr, timeout)?;
        write_request(
            &mut stream,
            &Request::Report {
                cohort,
                round,
                client: client as u32,
                spec: *spec,
                deadline_ms,
                msg: msg.clone(),
            },
        )
        .map_err(|e| TransportError::from_io(&e))?;
        match read_response(&mut stream)? {
            Response::Estimate {
                received,
                expected,
                partial,
                estimate,
            } => Ok(EstimateOut {
                estimate,
                received: received as usize,
                expected: expected as usize,
                partial,
            }),
            Response::Busy { retry_after_ms } => {
                Err(TransportError::Overloaded { retry_after_ms })
            }
            Response::Error(reason) => Err(TransportError::Rejected(reason)),
            other => Err(TransportError::Rejected(format!(
                "unexpected response to a report: {other:?}"
            ))),
        }
    })
}

/// Fetch the per-cohort traffic/round statistics. Idempotent, so
/// transient dial/read failures and Busy sheds are retried through the
/// shared schedule.
pub fn fetch_stats(addr: &str, timeout: Duration) -> Result<Vec<CohortStats>, TransportError> {
    retry_transient(&RetrySchedule::default(), 1, true, || {
        let mut stream = connect(addr, timeout)?;
        write_request(&mut stream, &Request::Health).map_err(|e| TransportError::from_io(&e))?;
        match read_response(&mut stream)? {
            Response::Stats(stats) => Ok(stats),
            Response::Busy { retry_after_ms } => {
                Err(TransportError::Overloaded { retry_after_ms })
            }
            Response::Error(reason) => Err(TransportError::Rejected(reason)),
            other => Err(TransportError::Rejected(format!(
                "unexpected response to a health request: {other:?}"
            ))),
        }
    })
}

/// Ask a service to exit its accept loop (open rounds close partial).
/// Idempotent (a second shutdown of a stopping service is a no-op), so
/// transient failures are retried like [`fetch_stats`].
pub fn request_shutdown(addr: &str, timeout: Duration) -> Result<(), TransportError> {
    retry_transient(&RetrySchedule::default(), 2, true, || {
        let mut stream = connect(addr, timeout)?;
        write_request(&mut stream, &Request::Shutdown).map_err(|e| TransportError::from_io(&e))?;
        match read_response(&mut stream)? {
            Response::Ok => Ok(()),
            Response::Busy { retry_after_ms } => {
                Err(TransportError::Overloaded { retry_after_ms })
            }
            Response::Error(reason) => Err(TransportError::Rejected(reason)),
            other => Err(TransportError::Rejected(format!(
                "unexpected response to a shutdown request: {other:?}"
            ))),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CodecSpec;

    fn spec(n: usize, d: usize) -> CohortSpec {
        CohortSpec {
            n,
            d,
            spec: CodecSpec::Lq { q: 64 },
            y: 8.0,
            seed: 11,
        }
    }

    fn spawn_server(opts: ServeOpts) -> (String, thread::JoinHandle<ServeSummary>) {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
        let addr = listener.local_addr().expect("local addr").to_string();
        let h = thread::Builder::new()
            .name("dme-serve".into())
            .spawn(move || serve(listener, opts).expect("serve"))
            .expect("spawn server");
        (addr, h)
    }

    #[test]
    fn one_cohort_round_over_loopback() {
        let (addr, server) = spawn_server(ServeOpts {
            max_rounds: Some(1),
            ..ServeOpts::default()
        });
        let cs = spec(3, 8);
        let clients: Vec<_> = (0..3)
            .map(|c| {
                let addr = addr.clone();
                thread::spawn(move || {
                    let x = vec![c as f64; 8];
                    report_round(&addr, 1, 0, c, &spec(3, 8), &x, 0, Duration::from_secs(10))
                        .expect("report")
                })
            })
            .collect();
        let outs: Vec<EstimateOut> = clients.into_iter().map(|h| h.join().unwrap()).collect();
        let summary = server.join().unwrap();
        // All three clients see the identical full-participation mean.
        assert_eq!(outs[0], outs[1]);
        assert_eq!(outs[1], outs[2]);
        assert_eq!(outs[0].received, 3);
        assert!(!outs[0].partial);
        for &v in &outs[0].estimate {
            assert!((v - 1.0).abs() < 0.3, "mean {v} far from 1.0");
        }
        assert_eq!(summary.rounds_completed, 1);
        assert_eq!(summary.cohorts, 1);
        // Paper accounting: 3 reports in, 3 × 64·d bits out.
        assert_eq!(summary.traffic.recv_msgs, 3);
        assert_eq!(summary.traffic.sent_bits, 3 * 64 * cs.d as u64);
    }

    #[test]
    fn deadline_closes_round_partial_and_answers_waiter() {
        let (addr, server) = spawn_server(ServeOpts {
            max_rounds: Some(1),
            ..ServeOpts::default()
        });
        // Only 1 of 2 expected clients reports; a 150 ms deadline closes
        // the round with the k=1 renormalized mean.
        let cs = spec(2, 4);
        let out = report_round(
            &addr,
            9,
            5,
            0,
            &cs,
            &[2.0, 2.0, 2.0, 2.0],
            150,
            Duration::from_secs(10),
        )
        .expect("report");
        assert_eq!(out.received, 1);
        assert_eq!(out.expected, 2);
        assert!(out.partial);
        for &v in &out.estimate {
            assert!((v - 2.0).abs() < 0.3, "k=1 mean {v} far from 2.0");
        }
        let summary = server.join().unwrap();
        assert_eq!(summary.rounds_partial, 1);
    }

    #[test]
    fn deadline_fires_under_sustained_accept_traffic() {
        let (addr, server) = spawn_server(ServeOpts {
            max_rounds: Some(1),
            ..ServeOpts::default()
        });
        // 1 of 2 expected clients reports with a 120 ms deadline, then
        // parks as a waiter.
        let cs = spec(2, 4);
        let reporter = {
            let addr = addr.clone();
            thread::spawn(move || {
                report_round(&addr, 4, 0, 0, &cs, &[3.0; 4], 120, Duration::from_secs(10))
            })
        };
        // Sustained traffic: hammer the service with health requests
        // while the round ages past its deadline. The connection
        // handlers themselves must sweep the expiry — the waiter cannot
        // depend on the accept thread winning the contended state lock.
        let until = Instant::now() + Duration::from_millis(500);
        while Instant::now() < until {
            let _ = fetch_stats(&addr, Duration::from_millis(500));
        }
        let out = reporter.join().unwrap().expect("waiter answered at the deadline");
        assert!(out.partial);
        assert_eq!(out.received, 1);
        assert_eq!(out.expected, 2);
        for &v in &out.estimate {
            assert!((v - 3.0).abs() < 0.3, "k=1 mean {v} far from 3.0");
        }
        let summary = server.join().unwrap();
        assert_eq!(summary.rounds_partial, 1);
    }

    #[test]
    fn health_and_shutdown_endpoints() {
        let (addr, server) = spawn_server(ServeOpts::default());
        let stats = fetch_stats(&addr, Duration::from_secs(5)).expect("health");
        assert!(stats.is_empty(), "no cohorts seen yet");
        request_shutdown(&addr, Duration::from_secs(5)).expect("shutdown");
        let summary = server.join().unwrap();
        assert_eq!(summary.rounds_completed, 0);
    }

    #[test]
    fn rejected_report_surfaces_reason() {
        let (addr, server) = spawn_server(ServeOpts::default());
        let cs = CohortSpec {
            spec: CodecSpec::EfSign,
            ..spec(2, 4)
        };
        let err = report_round(&addr, 1, 0, 0, &cs, &[0.0; 4], 0, Duration::from_secs(5))
            .expect_err("stateful codec must be refused");
        assert!(matches!(err, TransportError::Rejected(_)), "got {err:?}");
        request_shutdown(&addr, Duration::from_secs(5)).expect("shutdown");
        server.join().unwrap();
    }

    /// A leader "killed" mid-round (its durable table dropped without
    /// closing the round) restarts via `serve` over the same data dir,
    /// recovers the WAL'd report, and finishes the round bit-identical
    /// to an uninterrupted leader.
    #[test]
    fn serve_recovers_a_killed_leaders_round_from_its_data_dir() {
        use crate::store::{DurabilityOpts, SyncPolicy};
        static N: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
        let dir = std::env::temp_dir().join(format!(
            "dme-serve-recover-{}-{}",
            std::process::id(),
            N.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cs = spec(2, 8);
        let key = CohortKey { cohort: 3, round: 1 };
        let x0 = vec![1.25; 8];
        let x1 = vec![-0.75; 8];
        let encode = |client: usize, x: &[f64]| {
            let mut codec = cohort_codec(&cs, key.round);
            let mut rng = client_encoder_rng(cs.seed, key.round, client);
            codec.encode(x, &mut rng)
        };
        let opts = DurabilityOpts {
            sync: SyncPolicy::Always,
            ..DurabilityOpts::new(&dir)
        };
        // "Crashed" leader: client 0's report hits the WAL, then the
        // process dies before the round closes.
        {
            let (mut table, _) = CohortTable::durable(&opts).expect("open store");
            match table.submit(key, &cs, 0, &encode(0, &x0), 0, 60_000) {
                Submit::Pending { .. } => {}
                other => panic!("expected Pending, got {other:?}"),
            }
        }
        // Restarted leader: `serve` recovers the open round; client 1's
        // TCP report completes it.
        let (addr, server) = spawn_server(ServeOpts {
            max_rounds: Some(1),
            durability: Some(opts),
            ..ServeOpts::default()
        });
        let out = report_round(
            &addr,
            key.cohort,
            key.round,
            1,
            &cs,
            &x1,
            60_000,
            Duration::from_secs(20),
        )
        .expect("report after recovery");
        let summary = server.join().unwrap();
        assert_eq!((out.received, out.expected, out.partial), (2, 2, false));
        // Bit-identical to an uninterrupted leader folding both reports.
        let mut plain = CohortTable::new();
        match plain.submit(key, &cs, 0, &encode(0, &x0), 0, 60_000) {
            Submit::Pending { .. } => {}
            other => panic!("expected Pending, got {other:?}"),
        }
        let want = match plain.submit(key, &cs, 1, &encode(1, &x1), 0, 60_000) {
            Submit::Complete(r) => r.estimate,
            other => panic!("expected Complete, got {other:?}"),
        };
        assert_eq!(out.estimate, want, "recovered round must be bit-identical");
        assert_eq!(summary.rounds_completed, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A drip-feeding client (1 byte per poll) is cut off by the
    /// connection-lifetime deadline while honest traffic keeps flowing.
    #[test]
    fn slow_loris_client_is_cut_off_by_the_connection_deadline() {
        let (addr, server) = spawn_server(ServeOpts {
            conn_deadline: Duration::from_millis(300),
            read_timeout: Duration::from_millis(100),
            ..ServeOpts::default()
        });
        let loris = {
            let addr = addr.clone();
            thread::spawn(move || {
                let mut s = TcpStream::connect(&addr).expect("loris connect");
                let start = Instant::now();
                // A valid magic + report kind keeps the parser hungry,
                // then the header drips in one byte per poll — each
                // individual read stays alive, so only the lifetime
                // deadline can cut this connection off (well before the
                // 10 s give-up horizon).
                let mut preamble = super::super::wire::REQ_MAGIC.to_le_bytes().to_vec();
                preamble.push(0); // KIND_REPORT
                if s.write_all(&preamble).is_err() {
                    return start.elapsed();
                }
                for _ in 0..333u32 {
                    if s.write_all(&[0u8]).is_err() || s.flush().is_err() {
                        return start.elapsed();
                    }
                    thread::sleep(Duration::from_millis(30));
                }
                start.elapsed()
            })
        };
        // Honest traffic is unaffected while the loris drips: an n=1
        // round completes immediately.
        let out = report_round(&addr, 2, 0, 0, &spec(1, 4), &[1.5; 4], 0, Duration::from_secs(10))
            .expect("honest report while loris drips");
        assert_eq!(out.received, 1);
        let lifetime = loris.join().unwrap();
        assert!(
            lifetime < Duration::from_secs(5),
            "loris connection survived {lifetime:?}, deadline did not fire"
        );
        request_shutdown(&addr, Duration::from_secs(5)).expect("shutdown");
        server.join().unwrap();
    }

    /// Admission control sheds a round the cap forbids with a typed
    /// `Busy`; the client's shared-backoff retry lands it once capacity
    /// frees up.
    #[test]
    fn shed_report_is_retried_to_success_when_capacity_frees() {
        let (addr, server) = spawn_server(ServeOpts {
            max_open_rounds: 1,
            ..ServeOpts::default()
        });
        // Cohort 1 opens the only allowed round and holds it until its
        // second report arrives (the 60 s deadline never fires here).
        let blocker = {
            let addr = addr.clone();
            thread::spawn(move || {
                report_round(&addr, 1, 0, 0, &spec(2, 4), &[1.0; 4], 60_000, Duration::from_secs(30))
            })
        };
        // Wait until the blocking round is actually open.
        loop {
            let stats = fetch_stats(&addr, Duration::from_secs(5)).expect("health");
            if stats.iter().any(|s| s.cohort == 1 && s.open_rounds > 0) {
                break;
            }
            thread::sleep(Duration::from_millis(5));
        }
        // Deterministic shed: with the only round slot held, a raw
        // (retry-free) report for cohort 2 must bounce with Busy.
        let cs = spec(1, 4);
        let encode2 = || {
            let mut codec = cohort_codec(&cs, 0);
            let mut rng = client_encoder_rng(cs.seed, 0, 0);
            codec.encode(&[4.0; 4], &mut rng)
        };
        let mut raw = TcpStream::connect(&addr).expect("connect");
        raw.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        write_request(
            &mut raw,
            &Request::Report {
                cohort: 2,
                round: 0,
                client: 0,
                spec: cs,
                deadline_ms: 0,
                msg: encode2(),
            },
        )
        .expect("write raw report");
        match read_response(&mut raw).expect("raw response") {
            Response::Busy { retry_after_ms } => assert!(retry_after_ms > 0),
            other => panic!("expected Busy under the round cap, got {other:?}"),
        }
        // Now race the retrying client against capacity freeing up: the
        // second cohort-1 report closes the blocking round, after which
        // one of cohort 2's backoff attempts must be admitted.
        let retrier = {
            let addr = addr.clone();
            thread::spawn(move || {
                report_round(&addr, 2, 0, 0, &cs, &[4.0; 4], 0, Duration::from_secs(10))
            })
        };
        thread::sleep(Duration::from_millis(60));
        let closer = report_round(&addr, 1, 0, 1, &spec(2, 4), &[3.0; 4], 60_000, Duration::from_secs(30))
            .expect("closing report");
        assert_eq!(closer.received, 2);
        let out = retrier.join().unwrap().expect("shed report must succeed on retry");
        assert_eq!(out.received, 1);
        let blocked = blocker.join().unwrap().expect("estimate");
        assert!(!blocked.partial);
        request_shutdown(&addr, Duration::from_secs(5)).expect("shutdown");
        let summary = server.join().unwrap();
        assert!(summary.shed >= 1, "the capped round must be accounted: {summary:?}");
        assert_eq!(summary.rounds_completed, 2);
    }

    /// The per-reporter token bucket sheds a flooding reporter with
    /// `Busy` while other reporters stay admitted.
    #[test]
    fn rate_limit_sheds_flooding_reporter_with_busy() {
        let (addr, server) = spawn_server(ServeOpts {
            // burst 1, no refill: a reporter's second report always
            // sheds — deterministic for the assertion below.
            rate_limit: Some(RateLimit { burst: 1.0, per_sec: 0.0 }),
            ..ServeOpts::default()
        });
        let cs = spec(2, 4);
        // Raw wire (no client-side retry): report 1 from client 0 parks.
        let encode = |client: usize| {
            let mut codec = cohort_codec(&cs, 0);
            let mut rng = client_encoder_rng(cs.seed, 0, client);
            codec.encode(&[2.0; 4], &mut rng)
        };
        let report_req = |client: usize| Request::Report {
            cohort: 9,
            round: 0,
            client: client as u32,
            spec: cs,
            deadline_ms: 60_000,
            msg: encode(client),
        };
        let mut parked = TcpStream::connect(&addr).expect("connect");
        parked.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        write_request(&mut parked, &report_req(0)).expect("write report");
        // Wait for it to register, then flood from the same reporter.
        loop {
            let stats = fetch_stats(&addr, Duration::from_secs(5)).expect("health");
            if stats.iter().any(|s| s.cohort == 9 && s.reports == 1) {
                break;
            }
            thread::sleep(Duration::from_millis(5));
        }
        let mut flood = TcpStream::connect(&addr).expect("connect");
        flood.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        write_request(&mut flood, &report_req(0)).expect("write flood");
        match read_response(&mut flood).expect("flood response") {
            Response::Busy { retry_after_ms } => assert!(retry_after_ms > 0),
            other => panic!("expected Busy for the flooding reporter, got {other:?}"),
        }
        // A different reporter still has its own bucket: client 1
        // completes the round, which also answers the parked stream.
        let mut other = TcpStream::connect(&addr).expect("connect");
        other.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        write_request(&mut other, &report_req(1)).expect("write report");
        match read_response(&mut other).expect("closing response") {
            Response::Estimate { received, .. } => assert_eq!(received, 2),
            other => panic!("expected Estimate, got {other:?}"),
        }
        match read_response(&mut parked).expect("parked response") {
            Response::Estimate { received, .. } => assert_eq!(received, 2),
            other => panic!("expected Estimate, got {other:?}"),
        }
        request_shutdown(&addr, Duration::from_secs(5)).expect("shutdown");
        let summary = server.join().unwrap();
        assert_eq!(summary.shed, 1);
    }

    /// Honest rounds under `screen=distance` are bit-identical to the
    /// unscreened service, end to end over loopback.
    #[test]
    fn screened_service_matches_unscreened_bit_for_bit() {
        let mut run = |mode: ScreenMode| {
            let (addr, server) = spawn_server(ServeOpts {
                max_rounds: Some(1),
                screen: mode,
                ..ServeOpts::default()
            });
            let handles: Vec<_> = (0..2)
                .map(|c| {
                    let addr = addr.clone();
                    thread::spawn(move || {
                        let x: Vec<f64> = (0..8).map(|i| (c as f64 + 1.0) * (i as f64 - 3.5)).collect();
                        report_round(&addr, 3, 1, c, &spec(2, 8), &x, 0, Duration::from_secs(10))
                            .expect("report")
                    })
                })
                .collect();
            let outs: Vec<EstimateOut> = handles.into_iter().map(|h| h.join().unwrap()).collect();
            let summary = server.join().unwrap();
            assert_eq!(summary.quarantined, 0);
            assert_eq!(summary.shed, 0);
            outs
        };
        let off = run(ScreenMode::Off);
        let screened = run(ScreenMode::Distance);
        // n=2 folds commute bitwise, so arrival order cannot perturb
        // this comparison.
        assert_eq!(off[0].estimate, screened[0].estimate);
        assert_eq!(off[0].estimate, off[1].estimate);
    }
}
