//! The multi-cohort DME service: one leader process folding the
//! quantized reports of many independent client cohorts.
//!
//! Server side, [`serve`] runs an accept loop over a caller-bound
//! `TcpListener`: every connection carries one [`super::wire::Request`]
//! and gets one [`super::wire::Response`] (the one-round-trip shape of a
//! star round — the client *is* a star worker, the service *is* the
//! leader). Reports are folded through the [`super::cohort::CohortTable`]
//! streaming accumulator; a report that completes its round answers
//! everyone still parked on that round. Deadline sweeping runs on
//! *every* path that takes the state lock: the accept loop sweeps each
//! iteration (idle ticks included), and every connection handler sweeps
//! before dispatching its request — so under sustained accept traffic,
//! where handler threads dominate the lock, overdue rounds are still
//! expired and their waiters answered with the `1/k`-renormalized
//! partial mean instead of waiting for the accept thread to win the
//! lock.
//!
//! Client side, [`report_round`] encodes one vector under the cohort
//! codec convention (see [`super::cohort`]) and blocks for the round's
//! estimate; [`fetch_stats`] and [`request_shutdown`] drive the health
//! and shutdown endpoints. The `dme serve` / `dme report` CLI
//! subcommands are thin wrappers over these.
//!
//! Bit accounting follows the paper's per-machine model (see the `net`
//! module docs): each accepted report charges its metered `msg.bits`
//! inbound, each estimate delivery charges `64·d` outbound, framing is
//! excluded.

use super::cohort::{
    client_encoder_rng, cohort_codec, CohortKey, CohortSpec, CohortStats, CohortTable, RoundResult,
    Submit,
};
use super::error::TransportError;
use crate::store::DurabilityOpts;
use super::wire::{read_request, read_response, write_request, write_response, Request, Response};
use super::Traffic;
use std::collections::HashMap;
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Server knobs. `Default` is sized for tests and the CI smoke run;
/// long-running deployments mostly raise `max_rounds` to `None`.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Round deadline applied when a report carries `deadline_ms == 0`.
    pub default_deadline_ms: u64,
    /// Exit the accept loop after this many completed rounds
    /// (`None` = run until a shutdown request).
    pub max_rounds: Option<u64>,
    /// Per-connection read timeout — a silent client cannot park a
    /// handler thread forever.
    pub read_timeout: Duration,
    /// When set, the table is durable: reports are WAL'd before the
    /// fold, accumulators spill past the memory budget, and [`serve`]
    /// recovers open rounds from the data dir on startup (see
    /// [`crate::store`]).
    pub durability: Option<DurabilityOpts>,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            default_deadline_ms: 2_000,
            max_rounds: None,
            read_timeout: Duration::from_secs(10),
            durability: None,
        }
    }
}

/// What one [`serve`] run did, for logs and tests.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServeSummary {
    /// Rounds closed (full and partial).
    pub rounds_completed: u64,
    /// Rounds closed at their deadline with k < n reports.
    pub rounds_partial: u64,
    /// Distinct cohorts seen.
    pub cohorts: usize,
    /// Aggregate traffic from the server's seat (recv = reports in,
    /// sent = estimates out), paper units.
    pub traffic: Traffic,
}

struct State {
    table: super::cohort::CohortTable,
    /// Connections parked until their `(cohort, round)` closes.
    waiters: HashMap<CohortKey, Vec<TcpStream>>,
    rounds_completed: u64,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    start: Instant,
    opts: ServeOpts,
}

impl Shared {
    fn now_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }
}

/// Write a round's estimate to one stream, best-effort (a waiter that
/// hung up is simply skipped; the round result is unaffected).
fn send_estimate(stream: &mut TcpStream, r: &RoundResult) -> bool {
    let resp = Response::Estimate {
        received: r.received as u32,
        expected: r.expected as u32,
        partial: r.partial,
        estimate: r.estimate.clone(),
    };
    write_response(stream, &resp).and_then(|()| stream.flush()).is_ok()
}

/// Answer everyone parked on `key` (plus `also`, the report that closed
/// the round, if any) and charge the outbound estimate bits for the
/// deliveries that succeeded. Returns delivered-count.
fn deliver_round(
    state: &mut State,
    key: CohortKey,
    r: &RoundResult,
    also: Option<&mut TcpStream>,
) -> usize {
    let d = r.estimate.len();
    let mut delivered = 0;
    if let Some(stream) = also {
        if send_estimate(stream, r) {
            delivered += 1;
        }
    }
    if let Some(parked) = state.waiters.remove(&key) {
        for mut s in parked {
            if send_estimate(&mut s, r) {
                delivered += 1;
            }
        }
    }
    state.table.note_estimates_sent(key.cohort, d, delivered);
    delivered
}

/// Close every overdue round (all of them, at shutdown) and answer the
/// parked waiters with the renormalized partial means.
fn sweep(shared: &Shared, state: &mut State, force_all: bool) {
    let now = if force_all { u64::MAX } else { shared.now_ms() };
    for (key, r) in state.table.expire(now) {
        state.rounds_completed += 1;
        deliver_round(state, key, &r, None);
    }
    if let Some(cap) = shared.opts.max_rounds {
        if state.rounds_completed >= cap {
            state.shutdown = true;
        }
    }
}

/// Handle one connection: one request, at most one response. A report
/// whose round is still pending parks the stream in the waiter table
/// and returns — the closing report or the deadline sweeper answers it.
fn handle_connection(shared: &Arc<Shared>, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(shared.opts.read_timeout));
    let _ = stream.set_nodelay(true);
    let req = match read_request(&mut stream) {
        Ok(req) => req,
        Err(e) => {
            let _ = write_response(&mut stream, &Response::Error(e.to_string()));
            return;
        }
    };
    let mut state = shared.state.lock().expect("service state lock");
    // Sweep overdue rounds on the handling path too: with many handler
    // threads contending for the lock, the accept loop's sweep can be
    // starved indefinitely, and a parked waiter must not outlive its
    // round's deadline just because the service is busy. This also
    // guarantees a report racing its own deadline observes the expiry
    // (and is answered `Late`) rather than reopening a closed round.
    sweep(shared, &mut state, false);
    match req {
        Request::Report {
            cohort,
            round,
            client,
            spec,
            deadline_ms,
            msg,
        } => {
            if state.shutdown {
                drop(state);
                let reason = "service shutting down".to_string();
                let _ = write_response(&mut stream, &Response::Error(reason));
                return;
            }
            let key = CohortKey { cohort, round };
            let deadline = if deadline_ms == 0 {
                shared.opts.default_deadline_ms
            } else {
                u64::from(deadline_ms)
            };
            let now = shared.now_ms();
            match state.table.submit(key, &spec, client as usize, &msg, now, deadline) {
                Submit::Pending { .. } => {
                    // Park; the stream is answered when the round closes.
                    state.waiters.entry(key).or_default().push(stream);
                }
                Submit::Complete(r) => {
                    state.rounds_completed += 1;
                    deliver_round(&mut state, key, &r, Some(&mut stream));
                    if let Some(cap) = shared.opts.max_rounds {
                        if state.rounds_completed >= cap {
                            state.shutdown = true;
                        }
                    }
                }
                Submit::Late(r) => {
                    if send_estimate(&mut stream, &r) {
                        state.table.note_estimates_sent(key.cohort, r.estimate.len(), 1);
                    }
                }
                Submit::Rejected(reason) => {
                    drop(state);
                    let _ = write_response(&mut stream, &Response::Error(reason));
                }
            }
        }
        Request::Health => {
            let stats = state.table.stats();
            drop(state);
            let _ = write_response(&mut stream, &Response::Stats(stats));
        }
        Request::Shutdown => {
            state.shutdown = true;
            drop(state);
            let _ = write_response(&mut stream, &Response::Ok);
        }
    }
}

/// Run the service over a caller-bound listener until `max_rounds`
/// rounds complete or a shutdown request arrives. The accept loop polls
/// (nonblocking accept + short sleep) so it doubles as the deadline
/// sweeper without a dedicated timer thread; at exit every still-open
/// round is force-closed and its waiters receive their partial means.
pub fn serve(listener: TcpListener, opts: ServeOpts) -> Result<ServeSummary, TransportError> {
    let table = match &opts.durability {
        // Recovery happens here, before the first accept: a killed
        // leader restarted over the same data dir replays its WAL and
        // resumes every open cohort round exactly where it stopped.
        Some(d) => CohortTable::durable(d).map(|(t, _)| t)?,
        None => CohortTable::new(),
    };
    serve_with_table(listener, opts, table)
}

/// [`serve`] over a caller-built table — the seam the CLI uses to print
/// its recovery report before the accept loop starts, and tests use to
/// pre-load state.
pub fn serve_with_table(
    listener: TcpListener,
    opts: ServeOpts,
    table: CohortTable,
) -> Result<ServeSummary, TransportError> {
    listener
        .set_nonblocking(true)
        .map_err(|e| TransportError::from_io(&e))?;
    let shared = Arc::new(Shared {
        state: Mutex::new(State {
            table,
            waiters: HashMap::new(),
            rounds_completed: 0,
            shutdown: false,
        }),
        start: Instant::now(),
        opts,
    });
    let mut handles = Vec::new();
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_nonblocking(false);
                let sh = Arc::clone(&shared);
                handles.push(
                    thread::Builder::new()
                        .name("dme-serve-conn".into())
                        .spawn(move || handle_connection(&sh, stream))
                        .expect("spawn connection handler"),
                );
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(2));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(TransportError::from_io(&e)),
        }
        let mut state = shared.state.lock().expect("service state lock");
        sweep(&shared, &mut state, false);
        if state.shutdown {
            // Answer every still-open round with its partial mean
            // before tearing the process down.
            sweep(&shared, &mut state, true);
            break;
        }
        drop(state);
    }
    drop(listener);
    for h in handles {
        let _ = h.join();
    }
    let state = shared.state.lock().expect("service state lock");
    let stats = state.table.stats();
    Ok(ServeSummary {
        rounds_completed: state.rounds_completed,
        rounds_partial: stats.iter().map(|s| s.rounds_partial).sum(),
        cohorts: stats.len(),
        traffic: state.table.total_traffic(),
    })
}

/// A client's view of a closed round.
#[derive(Clone, Debug, PartialEq)]
pub struct EstimateOut {
    pub estimate: Vec<f64>,
    pub received: usize,
    pub expected: usize,
    pub partial: bool,
}

fn connect(addr: &str, timeout: Duration) -> Result<TcpStream, TransportError> {
    let stream = TcpStream::connect(addr).map_err(|e| TransportError::Connect {
        addr: addr.to_string(),
        attempts: 1,
        last: e.to_string(),
    })?;
    stream
        .set_read_timeout(Some(timeout))
        .map_err(|e| TransportError::from_io(&e))?;
    let _ = stream.set_nodelay(true);
    Ok(stream)
}

/// Encode `input` under the cohort codec convention and report it for
/// `(cohort, round)`, blocking until the round closes (all `n` reports
/// in, or the deadline with `k ≤ n`) and returning the round's
/// estimate. `deadline_ms == 0` defers to the server's default.
#[allow(clippy::too_many_arguments)]
pub fn report_round(
    addr: &str,
    cohort: u64,
    round: u64,
    client: usize,
    spec: &CohortSpec,
    input: &[f64],
    deadline_ms: u32,
    timeout: Duration,
) -> Result<EstimateOut, TransportError> {
    assert_eq!(input.len(), spec.d, "input dimension must match the cohort spec");
    let mut codec = cohort_codec(spec, round);
    let mut rng = client_encoder_rng(spec.seed, round, client);
    let msg = codec.encode(input, &mut rng);
    let mut stream = connect(addr, timeout)?;
    write_request(
        &mut stream,
        &Request::Report {
            cohort,
            round,
            client: client as u32,
            spec: *spec,
            deadline_ms,
            msg,
        },
    )
    .map_err(|e| TransportError::from_io(&e))?;
    match read_response(&mut stream)? {
        Response::Estimate {
            received,
            expected,
            partial,
            estimate,
        } => Ok(EstimateOut {
            estimate,
            received: received as usize,
            expected: expected as usize,
            partial,
        }),
        Response::Error(reason) => Err(TransportError::Rejected(reason)),
        other => Err(TransportError::Rejected(format!(
            "unexpected response to a report: {other:?}"
        ))),
    }
}

/// Fetch the per-cohort traffic/round statistics.
pub fn fetch_stats(addr: &str, timeout: Duration) -> Result<Vec<CohortStats>, TransportError> {
    let mut stream = connect(addr, timeout)?;
    write_request(&mut stream, &Request::Health).map_err(|e| TransportError::from_io(&e))?;
    match read_response(&mut stream)? {
        Response::Stats(stats) => Ok(stats),
        Response::Error(reason) => Err(TransportError::Rejected(reason)),
        other => Err(TransportError::Rejected(format!(
            "unexpected response to a health request: {other:?}"
        ))),
    }
}

/// Ask a service to exit its accept loop (open rounds close partial).
pub fn request_shutdown(addr: &str, timeout: Duration) -> Result<(), TransportError> {
    let mut stream = connect(addr, timeout)?;
    write_request(&mut stream, &Request::Shutdown).map_err(|e| TransportError::from_io(&e))?;
    match read_response(&mut stream)? {
        Response::Ok => Ok(()),
        Response::Error(reason) => Err(TransportError::Rejected(reason)),
        other => Err(TransportError::Rejected(format!(
            "unexpected response to a shutdown request: {other:?}"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CodecSpec;

    fn spec(n: usize, d: usize) -> CohortSpec {
        CohortSpec {
            n,
            d,
            spec: CodecSpec::Lq { q: 64 },
            y: 8.0,
            seed: 11,
        }
    }

    fn spawn_server(opts: ServeOpts) -> (String, thread::JoinHandle<ServeSummary>) {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
        let addr = listener.local_addr().expect("local addr").to_string();
        let h = thread::Builder::new()
            .name("dme-serve".into())
            .spawn(move || serve(listener, opts).expect("serve"))
            .expect("spawn server");
        (addr, h)
    }

    #[test]
    fn one_cohort_round_over_loopback() {
        let (addr, server) = spawn_server(ServeOpts {
            max_rounds: Some(1),
            ..ServeOpts::default()
        });
        let cs = spec(3, 8);
        let clients: Vec<_> = (0..3)
            .map(|c| {
                let addr = addr.clone();
                thread::spawn(move || {
                    let x = vec![c as f64; 8];
                    report_round(&addr, 1, 0, c, &spec(3, 8), &x, 0, Duration::from_secs(10))
                        .expect("report")
                })
            })
            .collect();
        let outs: Vec<EstimateOut> = clients.into_iter().map(|h| h.join().unwrap()).collect();
        let summary = server.join().unwrap();
        // All three clients see the identical full-participation mean.
        assert_eq!(outs[0], outs[1]);
        assert_eq!(outs[1], outs[2]);
        assert_eq!(outs[0].received, 3);
        assert!(!outs[0].partial);
        for &v in &outs[0].estimate {
            assert!((v - 1.0).abs() < 0.3, "mean {v} far from 1.0");
        }
        assert_eq!(summary.rounds_completed, 1);
        assert_eq!(summary.cohorts, 1);
        // Paper accounting: 3 reports in, 3 × 64·d bits out.
        assert_eq!(summary.traffic.recv_msgs, 3);
        assert_eq!(summary.traffic.sent_bits, 3 * 64 * cs.d as u64);
    }

    #[test]
    fn deadline_closes_round_partial_and_answers_waiter() {
        let (addr, server) = spawn_server(ServeOpts {
            max_rounds: Some(1),
            ..ServeOpts::default()
        });
        // Only 1 of 2 expected clients reports; a 150 ms deadline closes
        // the round with the k=1 renormalized mean.
        let cs = spec(2, 4);
        let out = report_round(
            &addr,
            9,
            5,
            0,
            &cs,
            &[2.0, 2.0, 2.0, 2.0],
            150,
            Duration::from_secs(10),
        )
        .expect("report");
        assert_eq!(out.received, 1);
        assert_eq!(out.expected, 2);
        assert!(out.partial);
        for &v in &out.estimate {
            assert!((v - 2.0).abs() < 0.3, "k=1 mean {v} far from 2.0");
        }
        let summary = server.join().unwrap();
        assert_eq!(summary.rounds_partial, 1);
    }

    #[test]
    fn deadline_fires_under_sustained_accept_traffic() {
        let (addr, server) = spawn_server(ServeOpts {
            max_rounds: Some(1),
            ..ServeOpts::default()
        });
        // 1 of 2 expected clients reports with a 120 ms deadline, then
        // parks as a waiter.
        let cs = spec(2, 4);
        let reporter = {
            let addr = addr.clone();
            thread::spawn(move || {
                report_round(&addr, 4, 0, 0, &cs, &[3.0; 4], 120, Duration::from_secs(10))
            })
        };
        // Sustained traffic: hammer the service with health requests
        // while the round ages past its deadline. The connection
        // handlers themselves must sweep the expiry — the waiter cannot
        // depend on the accept thread winning the contended state lock.
        let until = Instant::now() + Duration::from_millis(500);
        while Instant::now() < until {
            let _ = fetch_stats(&addr, Duration::from_millis(500));
        }
        let out = reporter.join().unwrap().expect("waiter answered at the deadline");
        assert!(out.partial);
        assert_eq!(out.received, 1);
        assert_eq!(out.expected, 2);
        for &v in &out.estimate {
            assert!((v - 3.0).abs() < 0.3, "k=1 mean {v} far from 3.0");
        }
        let summary = server.join().unwrap();
        assert_eq!(summary.rounds_partial, 1);
    }

    #[test]
    fn health_and_shutdown_endpoints() {
        let (addr, server) = spawn_server(ServeOpts::default());
        let stats = fetch_stats(&addr, Duration::from_secs(5)).expect("health");
        assert!(stats.is_empty(), "no cohorts seen yet");
        request_shutdown(&addr, Duration::from_secs(5)).expect("shutdown");
        let summary = server.join().unwrap();
        assert_eq!(summary.rounds_completed, 0);
    }

    #[test]
    fn rejected_report_surfaces_reason() {
        let (addr, server) = spawn_server(ServeOpts::default());
        let cs = CohortSpec {
            spec: CodecSpec::EfSign,
            ..spec(2, 4)
        };
        let err = report_round(&addr, 1, 0, 0, &cs, &[0.0; 4], 0, Duration::from_secs(5))
            .expect_err("stateful codec must be refused");
        assert!(matches!(err, TransportError::Rejected(_)), "got {err:?}");
        request_shutdown(&addr, Duration::from_secs(5)).expect("shutdown");
        server.join().unwrap();
    }

    /// A leader "killed" mid-round (its durable table dropped without
    /// closing the round) restarts via `serve` over the same data dir,
    /// recovers the WAL'd report, and finishes the round bit-identical
    /// to an uninterrupted leader.
    #[test]
    fn serve_recovers_a_killed_leaders_round_from_its_data_dir() {
        use crate::store::{DurabilityOpts, SyncPolicy};
        static N: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
        let dir = std::env::temp_dir().join(format!(
            "dme-serve-recover-{}-{}",
            std::process::id(),
            N.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cs = spec(2, 8);
        let key = CohortKey { cohort: 3, round: 1 };
        let x0 = vec![1.25; 8];
        let x1 = vec![-0.75; 8];
        let encode = |client: usize, x: &[f64]| {
            let mut codec = cohort_codec(&cs, key.round);
            let mut rng = client_encoder_rng(cs.seed, key.round, client);
            codec.encode(x, &mut rng)
        };
        let opts = DurabilityOpts {
            sync: SyncPolicy::Always,
            ..DurabilityOpts::new(&dir)
        };
        // "Crashed" leader: client 0's report hits the WAL, then the
        // process dies before the round closes.
        {
            let (mut table, _) = CohortTable::durable(&opts).expect("open store");
            match table.submit(key, &cs, 0, &encode(0, &x0), 0, 60_000) {
                Submit::Pending { .. } => {}
                other => panic!("expected Pending, got {other:?}"),
            }
        }
        // Restarted leader: `serve` recovers the open round; client 1's
        // TCP report completes it.
        let (addr, server) = spawn_server(ServeOpts {
            max_rounds: Some(1),
            durability: Some(opts),
            ..ServeOpts::default()
        });
        let out = report_round(
            &addr,
            key.cohort,
            key.round,
            1,
            &cs,
            &x1,
            60_000,
            Duration::from_secs(20),
        )
        .expect("report after recovery");
        let summary = server.join().unwrap();
        assert_eq!((out.received, out.expected, out.partial), (2, 2, false));
        // Bit-identical to an uninterrupted leader folding both reports.
        let mut plain = CohortTable::new();
        match plain.submit(key, &cs, 0, &encode(0, &x0), 0, 60_000) {
            Submit::Pending { .. } => {}
            other => panic!("expected Pending, got {other:?}"),
        }
        let want = match plain.submit(key, &cs, 1, &encode(1, &x1), 0, 60_000) {
            Submit::Complete(r) => r.estimate,
            other => panic!("expected Complete, got {other:?}"),
        };
        assert_eq!(out.estimate, want, "recovered round must be bit-identical");
        assert_eq!(summary.rounds_completed, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
