//! Report screening — the validation pass between the wire and the fold.
//!
//! The service edge cannot trust a report just because it parsed: a
//! frame of the right shape can still carry a payload that panics the
//! decoder (short bit streams — [`crate::quant::bits::BitReader`] reads
//! past the end of a truncated message), poisons the accumulator
//! (NaN/Inf smuggled through a codec's float header), or drags the
//! estimate arbitrarily far off (a huge-norm payload in an otherwise
//! well-formed message). Screening runs *before* the report touches the
//! WAL or the accumulator, so a screened-out report is bit-invisible:
//! the fold, the durability log and the delivered estimates are
//! identical to a run where the report never arrived.
//!
//! Three levels, selected by [`ScreenMode`]:
//!
//! - **Off** — today's behavior, bit for bit. No probe is built, no
//!   extra decode happens, accepted reports take the fused
//!   `decode_accumulate_into` path unchanged.
//! - **Basic** — spec hygiene (`y` finite and positive) plus *size
//!   coherence*: the expected `(bits, bytes)` of a well-formed message
//!   is learned once per round by encoding the zero vector
//!   ([`RoundScreen::probe`] — every stateless codec's message size is a
//!   pure function of `(spec, round)`, independent of the input), and
//!   any mismatch is shed before the decoder ever sees the bytes. This
//!   is the panic guard: the bit-packed decoders assume length-checked
//!   messages. Accepted reports are then decoded to a scratch buffer and
//!   checked for NaN/Inf (float hygiene) before folding.
//! - **Distance** — Basic plus the paper-grounded distance filter. The
//!   paper's error bounds depend on the *distance between inputs*, not
//!   their norms; under the cohort convention the decode reference is
//!   the zero vector and `spec.y` is an ℓ∞ bound on the client vectors
//!   themselves, so an honest decoded report satisfies
//!   `‖z‖∞ ≤ y + (quantization radius)`. A decoded vector with
//!   `‖z‖∞ > slack · y` (slack defaults to [`DEFAULT_SLACK`], comfortably
//!   above any codec's radius at sane `q`) is implausible for *any*
//!   in-spec input and is quarantined rather than folded.
//!
//! Screening verdicts are typed ([`Verdict`]): `Shed` for reports
//! refused before decode (malformed frames — the sender is broken or
//! hostile), `Quarantine` for reports that decoded to implausible
//! values (corruption or an adversary). Both leave the round's
//! accumulator and WAL untouched; per-cohort tallies surface through
//! the health endpoint ([`super::cohort::CohortStats`]).
//!
//! Bit-identity of the screened accept path: the [`crate::quant::VectorCodec`]
//! contract pins `decode_accumulate_into(msg, ref, w, acc)` to be
//! IEEE-op-for-op identical to `decode_into(msg, ref, z)` followed by
//! `axpy(acc, w, z)`. Screening decodes to `z` anyway (it has to look at
//! the values), so folding the already-decoded scratch via `axpy` gives
//! accumulators — and therefore estimates — bit-identical to the
//! unscreened fused path.

use super::cohort::{cohort_codec, CohortSpec};
use crate::quant::Message;
use crate::rng::{hash2, Rng};

/// Default ℓ∞ plausibility slack for [`ScreenMode::Distance`]:
/// quarantine decoded reports with `‖z‖∞ > slack · y`. An honest decode
/// is within the codec's quantization radius of an input bounded by `y`,
/// so 2 leaves generous headroom at any sane `q`.
pub const DEFAULT_SLACK: f64 = 2.0;

/// How aggressively the service screens reports before folding them.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ScreenMode {
    /// No screening — bit-identical to the pre-screening service.
    #[default]
    Off,
    /// Frame/size coherence + float hygiene on the decoded vector.
    Basic,
    /// `Basic` + the distance filter (`‖z‖∞ ≤ slack · y`).
    Distance,
}

impl std::str::FromStr for ScreenMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "off" => Ok(ScreenMode::Off),
            "basic" => Ok(ScreenMode::Basic),
            "distance" => Ok(ScreenMode::Distance),
            other => Err(format!("unknown screen mode '{other}' (off|basic|distance)")),
        }
    }
}

impl ScreenMode {
    pub fn label(&self) -> &'static str {
        match self {
            ScreenMode::Off => "off",
            ScreenMode::Basic => "basic",
            ScreenMode::Distance => "distance",
        }
    }
}

/// Per-cohort screening tallies, derived from
/// [`super::cohort::CohortStats`] (`accepted` = folded reports).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScreenStats {
    pub accepted: u64,
    /// Refused before decode (size/coherence) or by admission control.
    pub shed: u64,
    /// Decoded but implausible (NaN/Inf or distance filter).
    pub quarantined: u64,
}

/// A screening verdict for one report.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Verdict {
    Accept,
    /// Refused before decode: the message cannot be a well-formed
    /// encoding under this round's codec.
    Shed(String),
    /// Decoded, but the values are implausible for any in-spec input.
    Quarantine(String),
}

/// The per-round screening state: the exact `(bits, bytes)` every
/// well-formed message for this round must have.
///
/// Every stateless codec in the crate emits fixed-size messages — a
/// byte-aligned float header plus `d` (or `reps`) fixed-width fields —
/// so one probe encode of the zero vector at round open pins the size
/// for the whole round. The probe draws from its own RNG stream
/// (`hash2(round_seed, 0)`; clients use `hash2(round_seed, c + 1)`), so
/// it perturbs nothing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RoundScreen {
    pub expect_bits: u64,
    pub expect_len: usize,
}

impl RoundScreen {
    /// Learn the expected message size for `(spec, round)` by encoding
    /// the zero vector under the round's shared codec.
    pub fn probe(spec: &CohortSpec, round: u64) -> RoundScreen {
        let mut codec = cohort_codec(spec, round);
        let zeros = vec![0.0; spec.d];
        let mut rng = Rng::new(hash2(hash2(spec.seed, round), 0));
        let msg = codec.encode(&zeros, &mut rng);
        RoundScreen {
            expect_bits: msg.bits,
            expect_len: msg.bytes.len(),
        }
    }

    /// Frame-level sanity: spec hygiene plus size coherence against the
    /// probe. Runs before any decode — this is what keeps truncated or
    /// padded bit streams away from the panic-on-overrun bit readers.
    pub fn screen_frame(&self, spec: &CohortSpec, msg: &Message) -> Result<(), String> {
        if !spec.y.is_finite() || spec.y <= 0.0 {
            return Err(format!("cohort y bound {} is not a positive finite float", spec.y));
        }
        if msg.bits > 8 * msg.bytes.len() as u64 {
            return Err(format!(
                "metered bits {} exceed payload capacity of {} bytes",
                msg.bits,
                msg.bytes.len()
            ));
        }
        if msg.bits != self.expect_bits || msg.bytes.len() != self.expect_len {
            return Err(format!(
                "message size ({} bits, {} bytes) does not match the round codec's ({} bits, {} bytes)",
                msg.bits,
                msg.bytes.len(),
                self.expect_bits,
                self.expect_len
            ));
        }
        Ok(())
    }
}

/// Value-level screen over a decoded report: float hygiene always, the
/// ℓ∞ distance filter under [`ScreenMode::Distance`].
pub fn screen_decoded(mode: ScreenMode, y: f64, slack: f64, z: &[f64]) -> Result<(), String> {
    let mut max_abs = 0.0f64;
    for &v in z {
        if !v.is_finite() {
            return Err("decoded report contains a non-finite value".to_string());
        }
        max_abs = max_abs.max(v.abs());
    }
    if mode == ScreenMode::Distance && max_abs > slack * y {
        return Err(format!(
            "decoded report has ℓ∞ norm {max_abs:.3e}, implausibly far from the \
             shared estimate for a cohort with y={y} (limit {:.3e})",
            slack * y
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CodecSpec;
    use crate::net::cohort::client_encoder_rng;

    fn spec(codec: CodecSpec) -> CohortSpec {
        CohortSpec {
            n: 2,
            d: 16,
            spec: codec,
            y: 8.0,
            seed: 5,
        }
    }

    /// Every stateless codec's message size must be input-independent —
    /// the invariant the probe-equality screen rests on.
    #[test]
    fn probe_size_matches_honest_messages_for_every_stateless_codec() {
        let codecs = [
            CodecSpec::Lq { q: 64 },
            CodecSpec::Rlq { q: 16 },
            CodecSpec::LqHull { q: 8 },
            CodecSpec::D4 { q: 16 },
            CodecSpec::QsgdL2 { q: 16 },
            CodecSpec::QsgdLinf { q: 16 },
            CodecSpec::Hadamard { q: 16 },
            CodecSpec::Vqsgd { reps: 6 },
            CodecSpec::TernGrad,
            CodecSpec::Full,
        ];
        for c in codecs {
            let cs = spec(c);
            let probe = RoundScreen::probe(&cs, 3);
            for client in 0..2usize {
                let x: Vec<f64> = (0..cs.d)
                    .map(|i| ((client + 1) as f64) * ((i as f64 * 0.37).sin() * 6.0))
                    .collect();
                let mut codec = cohort_codec(&cs, 3);
                let mut rng = client_encoder_rng(cs.seed, 3, client);
                let msg = codec.encode(&x, &mut rng);
                assert_eq!(
                    (msg.bits, msg.bytes.len()),
                    (probe.expect_bits, probe.expect_len),
                    "{}: honest message size must equal the zero-probe size",
                    cs.spec.label()
                );
                assert!(probe.screen_frame(&cs, &msg).is_ok());
            }
        }
    }

    #[test]
    fn frame_screen_sheds_wrong_sizes_and_bad_specs() {
        let cs = spec(CodecSpec::Lq { q: 64 });
        let probe = RoundScreen::probe(&cs, 0);
        let mut codec = cohort_codec(&cs, 0);
        let mut rng = client_encoder_rng(cs.seed, 0, 0);
        let mut msg = codec.encode(&vec![1.0; cs.d], &mut rng);
        // Truncated payload (bits adjusted so the Message contract holds).
        msg.bytes.pop();
        msg.bits = 8 * msg.bytes.len() as u64;
        assert!(probe.screen_frame(&cs, &msg).is_err());
        // bits > 8·len violates the Message contract outright.
        let bad = Message {
            bytes: vec![0u8; probe.expect_len],
            bits: 8 * probe.expect_len as u64 + 1,
        };
        assert!(probe.screen_frame(&cs, &bad).is_err());
        // Non-finite y is refused before any decode.
        let ok = Message {
            bytes: vec![0u8; probe.expect_len],
            bits: probe.expect_bits,
        };
        let bad_spec = CohortSpec { y: f64::NAN, ..cs };
        assert!(probe.screen_frame(&bad_spec, &ok).is_err());
    }

    #[test]
    fn decoded_screen_catches_nan_and_distance() {
        let z_ok = vec![1.0, -7.5, 0.0];
        assert!(screen_decoded(ScreenMode::Basic, 8.0, DEFAULT_SLACK, &z_ok).is_ok());
        assert!(screen_decoded(ScreenMode::Distance, 8.0, DEFAULT_SLACK, &z_ok).is_ok());
        let z_nan = vec![1.0, f64::NAN];
        assert!(screen_decoded(ScreenMode::Basic, 8.0, DEFAULT_SLACK, &z_nan).is_err());
        let z_inf = vec![f64::INFINITY];
        assert!(screen_decoded(ScreenMode::Basic, 8.0, DEFAULT_SLACK, &z_inf).is_err());
        // Far-but-finite passes Basic, is quarantined by Distance.
        let z_far = vec![1.0e6];
        assert!(screen_decoded(ScreenMode::Basic, 8.0, DEFAULT_SLACK, &z_far).is_ok());
        assert!(screen_decoded(ScreenMode::Distance, 8.0, DEFAULT_SLACK, &z_far).is_err());
    }

    #[test]
    fn screen_mode_parses() {
        assert_eq!("off".parse::<ScreenMode>().unwrap(), ScreenMode::Off);
        assert_eq!("basic".parse::<ScreenMode>().unwrap(), ScreenMode::Basic);
        assert_eq!("distance".parse::<ScreenMode>().unwrap(), ScreenMode::Distance);
        assert!("paranoid".parse::<ScreenMode>().is_err());
    }
}
