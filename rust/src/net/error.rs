//! Typed transport failures.
//!
//! Every fallible operation in the transport layer returns
//! [`TransportError`] instead of panicking (the `expect("peer hung up")`
//! / `expect("cluster shut down")` panics of the pre-transport
//! substrate). The variants partition failures the way a caller has to
//! react to them: a single peer going away (`PeerClosed`) can be
//! survived by a service folding k ≤ n reports, a whole-cluster
//! `Shutdown` cannot; `Timeout` is retryable, `BadFrame` is not (the
//! stream is desynchronized and must be dropped).

use std::fmt;
use std::io;

/// Why a frame could not be decoded off a byte stream.
///
/// A frame error means the stream can no longer be trusted to be
/// aligned on a packet boundary: the connection must be closed, not
/// resynchronized.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FrameError {
    /// The stream ended in the middle of a frame (a clean close *between*
    /// frames is end-of-stream, not an error).
    ShortRead { needed: usize, got: usize },
    /// The length prefix exceeds the configured maximum frame size
    /// (defends the receiver against allocating attacker-chosen sizes).
    TooLarge { len: u32, max: u32 },
    /// The metered bit count exceeds the payload's byte capacity —
    /// impossible for a well-formed [`crate::quant::Message`], whose
    /// contract is `bits <= 8 * bytes.len()`.
    BitsExceedBytes { bits: u64, len: u32 },
    /// A service-protocol frame did not start with the expected magic.
    BadMagic { got: u32, want: u32 },
    /// A service-protocol frame had an unknown kind tag or a malformed
    /// fixed-size header.
    BadHeader(&'static str),
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::ShortRead { needed, got } => {
                write!(f, "short read: needed {needed} bytes, stream ended after {got}")
            }
            FrameError::TooLarge { len, max } => {
                write!(f, "frame length {len} exceeds maximum {max}")
            }
            FrameError::BitsExceedBytes { bits, len } => {
                write!(f, "metered bits {bits} exceed payload capacity of {len} bytes")
            }
            FrameError::BadMagic { got, want } => {
                write!(f, "bad magic {got:#010x} (expected {want:#010x})")
            }
            FrameError::BadHeader(what) => write!(f, "malformed header: {what}"),
        }
    }
}

/// A transport-layer failure, replacing the panicking channel paths.
#[derive(Clone, Debug, PartialEq)]
pub enum TransportError {
    /// The peer's endpoint is gone: its channel receiver or socket closed
    /// while we still had traffic for it.
    PeerClosed { peer: usize },
    /// The whole cluster is gone: every possible sender to this endpoint
    /// has been dropped, so no further packet can ever arrive.
    Shutdown,
    /// A machine thread panicked ([`crate::sim::Cluster::try_run`]'s
    /// graceful-shutdown path reports the panic instead of poisoning the
    /// process).
    WorkerPanicked { machine: usize },
    /// A receive deadline elapsed with no packet.
    Timeout { peer: Option<usize> },
    /// Could not establish a connection after bounded retries.
    Connect {
        addr: String,
        attempts: u32,
        last: String,
    },
    /// The mesh handshake was violated (wrong magic, duplicate or
    /// out-of-range machine id, mismatched cluster size).
    Handshake(String),
    /// A frame-level decode failure (see [`FrameError`]).
    BadFrame(FrameError),
    /// The DME service refused the request (spec mismatch, duplicate
    /// report, stateful codec, …) — a protocol-level rejection carried
    /// back over a healthy connection.
    Rejected(String),
    /// The service shed this request under overload (admission caps or
    /// rate limiting). Retryable by construction: the server suggests a
    /// backoff and [`super::service::report_round`] honors it through
    /// the shared [`super::retry::RetrySchedule`].
    Overloaded { retry_after_ms: u64 },
    /// A k-of-n round closed at its deadline with fewer reports than
    /// the straggler policy's minimum quorum. Recoverable: the session
    /// stays usable and the next round may succeed.
    QuorumFailed { got: usize, need: usize },
    /// An underlying I/O failure on an established stream.
    Io { kind: io::ErrorKind, detail: String },
}

impl TransportError {
    /// Wrap an `io::Error` (which is neither `Clone` nor `PartialEq`)
    /// into the comparable form tests can assert on.
    pub fn from_io(e: &io::Error) -> Self {
        TransportError::Io {
            kind: e.kind(),
            detail: e.to_string(),
        }
    }
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::PeerClosed { peer } => write!(f, "peer {peer} closed its endpoint"),
            TransportError::Shutdown => write!(f, "cluster shut down (all senders dropped)"),
            TransportError::WorkerPanicked { machine } => {
                write!(f, "machine {machine} panicked")
            }
            TransportError::Timeout { peer: Some(p) } => {
                write!(f, "timed out waiting for a packet from peer {p}")
            }
            TransportError::Timeout { peer: None } => write!(f, "timed out waiting for a packet"),
            TransportError::Connect { addr, attempts, last } => {
                write!(f, "could not connect to {addr} after {attempts} attempts: {last}")
            }
            TransportError::Handshake(why) => write!(f, "mesh handshake failed: {why}"),
            TransportError::BadFrame(fe) => write!(f, "bad frame: {fe}"),
            TransportError::Rejected(why) => write!(f, "service rejected the request: {why}"),
            TransportError::Overloaded { retry_after_ms } => {
                write!(f, "service overloaded, retry after {retry_after_ms}ms")
            }
            TransportError::QuorumFailed { got, need } => {
                write!(f, "round closed with {got} of the {need} reports its quorum requires")
            }
            TransportError::Io { kind, detail } => write!(f, "i/o error ({kind:?}): {detail}"),
        }
    }
}

impl std::error::Error for TransportError {}

impl From<FrameError> for TransportError {
    fn from(fe: FrameError) -> Self {
        TransportError::BadFrame(fe)
    }
}
