//! Wire encoding of the DME service protocol — the request/response
//! records `dme serve` and `dme report` exchange over a TCP stream.
//!
//! One connection carries one request and one response (the client
//! connects, reports, blocks for its estimate, disconnects — matching
//! the one-round-trip shape of a star round). Records are fixed-layout
//! little-endian headers; the quantized payload inside a report travels
//! as a [`crate::net::frame`] frame, i.e. the `PacketArena` format
//! verbatim, so the client→leader leg is byte-compatible with every
//! other transport in the crate. Malformed records are rejected with
//! typed [`TransportError`]s — a service must never panic on attacker-
//! controlled bytes.

use super::cohort::{CohortSpec, CohortStats};
use super::error::{FrameError, TransportError};
use super::frame;
use crate::coordinator::CodecSpec;
use crate::quant::Message;
use std::io::{self, Read, Write};

/// Request record magic: `"DMEq"`.
pub const REQ_MAGIC: u32 = u32::from_le_bytes(*b"DMEq");
/// Response record magic: `"DMEr"`.
pub const RESP_MAGIC: u32 = u32::from_le_bytes(*b"DMEr");

const KIND_REPORT: u8 = 0;
const KIND_HEALTH: u8 = 1;
const KIND_SHUTDOWN: u8 = 2;

const KIND_ESTIMATE: u8 = 0;
const KIND_ERROR: u8 = 1;
const KIND_STATS: u8 = 2;
const KIND_OK: u8 = 3;
const KIND_BUSY: u8 = 4;

/// Hard cap on `d` accepted over the wire (an estimate response of this
/// size is 64 MB — aligned with [`frame::MAX_FRAME_BYTES`]).
pub const MAX_WIRE_DIM: u32 = 8 << 20;

/// A client→service request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// One client's quantized report for one cohort round.
    Report {
        cohort: u64,
        round: u64,
        client: u32,
        spec: CohortSpec,
        /// Round deadline in ms, measured from when the first report
        /// opens the round on the server.
        deadline_ms: u32,
        msg: Message,
    },
    /// Per-cohort traffic/round statistics.
    Health,
    /// Ask the service to finish up and exit its accept loop.
    Shutdown,
}

/// A service→client response.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// The round's (possibly renormalized-partial) mean estimate.
    Estimate {
        received: u32,
        expected: u32,
        partial: bool,
        estimate: Vec<f64>,
    },
    /// The request was refused; the reason is human-readable.
    Error(String),
    /// Health answer: one entry per cohort ever seen.
    Stats(Vec<CohortStats>),
    /// Shutdown acknowledged.
    Ok,
    /// Load shed under overload: the request was refused *before* any
    /// state changed. Retryable after the suggested backoff — the
    /// client side maps this to [`TransportError::Overloaded`].
    Busy { retry_after_ms: u64 },
}

/// `CodecSpec` wire form: tag byte + one u32 parameter (unused
/// parameters are 0). Tags are append-only. Shared with the WAL/run
/// records in [`crate::store`], which persist specs in this encoding.
pub(crate) fn spec_to_wire(s: CodecSpec) -> (u8, u32) {
    match s {
        CodecSpec::Lq { q } => (0, q),
        CodecSpec::Rlq { q } => (1, q),
        CodecSpec::LqHull { q } => (2, q),
        CodecSpec::D4 { q } => (3, q),
        CodecSpec::QsgdL2 { q } => (4, q),
        CodecSpec::QsgdLinf { q } => (5, q),
        CodecSpec::Hadamard { q } => (6, q),
        CodecSpec::Vqsgd { reps } => (7, reps),
        CodecSpec::EfSign => (8, 0),
        CodecSpec::PowerSgd { rank } => (9, rank as u32),
        CodecSpec::TernGrad => (10, 0),
        CodecSpec::TopK { k } => (11, k as u32),
        CodecSpec::Full => (12, 0),
    }
}

pub(crate) fn spec_from_wire(tag: u8, param: u32) -> Result<CodecSpec, TransportError> {
    Ok(match tag {
        0 => CodecSpec::Lq { q: param },
        1 => CodecSpec::Rlq { q: param },
        2 => CodecSpec::LqHull { q: param },
        3 => CodecSpec::D4 { q: param },
        4 => CodecSpec::QsgdL2 { q: param },
        5 => CodecSpec::QsgdLinf { q: param },
        6 => CodecSpec::Hadamard { q: param },
        7 => CodecSpec::Vqsgd { reps: param },
        8 => CodecSpec::EfSign,
        9 => CodecSpec::PowerSgd {
            rank: param as usize,
        },
        10 => CodecSpec::TernGrad,
        11 => CodecSpec::TopK { k: param as usize },
        12 => CodecSpec::Full,
        _ => return Err(FrameError::BadHeader("unknown codec tag").into()),
    })
}

// --- little-endian primitives over a growing buffer / a Read ---------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn io_err(e: &io::Error) -> TransportError {
    if e.kind() == io::ErrorKind::UnexpectedEof {
        FrameError::BadHeader("record truncated").into()
    } else {
        TransportError::from_io(e)
    }
}

fn get_u8<R: Read>(r: &mut R) -> Result<u8, TransportError> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b).map_err(|e| io_err(&e))?;
    Ok(b[0])
}

fn get_u32<R: Read>(r: &mut R) -> Result<u32, TransportError> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).map_err(|e| io_err(&e))?;
    Ok(u32::from_le_bytes(b))
}

fn get_u64<R: Read>(r: &mut R) -> Result<u64, TransportError> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b).map_err(|e| io_err(&e))?;
    Ok(u64::from_le_bytes(b))
}

fn get_f64<R: Read>(r: &mut R) -> Result<f64, TransportError> {
    Ok(f64::from_bits(get_u64(r)?))
}

fn check_magic<R: Read>(r: &mut R, want: u32) -> Result<(), TransportError> {
    let got = get_u32(r)?;
    if got != want {
        return Err(FrameError::BadMagic { got, want }.into());
    }
    Ok(())
}

// --- requests --------------------------------------------------------

pub fn write_request<W: Write>(w: &mut W, req: &Request) -> io::Result<()> {
    let mut buf = Vec::new();
    put_u32(&mut buf, REQ_MAGIC);
    match req {
        Request::Report {
            cohort,
            round,
            client,
            spec,
            deadline_ms,
            msg,
        } => {
            buf.push(KIND_REPORT);
            put_u64(&mut buf, *cohort);
            put_u64(&mut buf, *round);
            put_u32(&mut buf, *client);
            put_u32(&mut buf, spec.n as u32);
            put_u32(&mut buf, spec.d as u32);
            let (tag, param) = spec_to_wire(spec.spec);
            buf.push(tag);
            put_u32(&mut buf, param);
            put_f64(&mut buf, spec.y);
            put_u64(&mut buf, spec.seed);
            put_u32(&mut buf, *deadline_ms);
            w.write_all(&buf)?;
            return frame::write_frame(w, msg);
        }
        Request::Health => buf.push(KIND_HEALTH),
        Request::Shutdown => buf.push(KIND_SHUTDOWN),
    }
    w.write_all(&buf)
}

pub fn read_request<R: Read>(r: &mut R) -> Result<Request, TransportError> {
    check_magic(r, REQ_MAGIC)?;
    match get_u8(r)? {
        KIND_REPORT => {
            let cohort = get_u64(r)?;
            let round = get_u64(r)?;
            let client = get_u32(r)?;
            let n = get_u32(r)?;
            let d = get_u32(r)?;
            if d > MAX_WIRE_DIM {
                return Err(FrameError::BadHeader("dimension over wire cap").into());
            }
            let tag = get_u8(r)?;
            let param = get_u32(r)?;
            let y = get_f64(r)?;
            let seed = get_u64(r)?;
            let deadline_ms = get_u32(r)?;
            let msg = frame::read_frame(r, frame::MAX_FRAME_BYTES)?
                .ok_or(FrameError::BadHeader("report missing payload frame"))?;
            Ok(Request::Report {
                cohort,
                round,
                client,
                spec: CohortSpec {
                    n: n as usize,
                    d: d as usize,
                    spec: spec_from_wire(tag, param)?,
                    y,
                    seed,
                },
                deadline_ms,
                msg,
            })
        }
        KIND_HEALTH => Ok(Request::Health),
        KIND_SHUTDOWN => Ok(Request::Shutdown),
        _ => Err(FrameError::BadHeader("unknown request kind").into()),
    }
}

// --- responses -------------------------------------------------------

pub fn write_response<W: Write>(w: &mut W, resp: &Response) -> io::Result<()> {
    let mut buf = Vec::new();
    put_u32(&mut buf, RESP_MAGIC);
    match resp {
        Response::Estimate {
            received,
            expected,
            partial,
            estimate,
        } => {
            buf.push(KIND_ESTIMATE);
            put_u32(&mut buf, *received);
            put_u32(&mut buf, *expected);
            buf.push(u8::from(*partial));
            put_u32(&mut buf, estimate.len() as u32);
            for &v in estimate {
                put_f64(&mut buf, v);
            }
        }
        Response::Error(reason) => {
            buf.push(KIND_ERROR);
            let bytes = reason.as_bytes();
            put_u32(&mut buf, bytes.len() as u32);
            buf.extend_from_slice(bytes);
        }
        Response::Stats(stats) => {
            buf.push(KIND_STATS);
            put_u32(&mut buf, stats.len() as u32);
            for s in stats {
                put_u64(&mut buf, s.cohort);
                put_u64(&mut buf, s.rounds_completed);
                put_u64(&mut buf, s.rounds_partial);
                put_u64(&mut buf, s.reports);
                put_u64(&mut buf, s.bits_in);
                put_u64(&mut buf, s.bits_out);
                put_u32(&mut buf, s.open_rounds);
                put_u64(&mut buf, s.shed);
                put_u64(&mut buf, s.quarantined);
                put_u64(&mut buf, s.resident_bytes);
            }
        }
        Response::Ok => buf.push(KIND_OK),
        Response::Busy { retry_after_ms } => {
            buf.push(KIND_BUSY);
            put_u64(&mut buf, *retry_after_ms);
        }
    }
    w.write_all(&buf)
}

pub fn read_response<R: Read>(r: &mut R) -> Result<Response, TransportError> {
    check_magic(r, RESP_MAGIC)?;
    match get_u8(r)? {
        KIND_ESTIMATE => {
            let received = get_u32(r)?;
            let expected = get_u32(r)?;
            let partial = get_u8(r)? != 0;
            let d = get_u32(r)?;
            if d > MAX_WIRE_DIM {
                return Err(FrameError::BadHeader("dimension over wire cap").into());
            }
            let mut estimate = Vec::with_capacity(d as usize);
            for _ in 0..d {
                estimate.push(get_f64(r)?);
            }
            Ok(Response::Estimate {
                received,
                expected,
                partial,
                estimate,
            })
        }
        KIND_ERROR => {
            let len = get_u32(r)?;
            if len > 1 << 20 {
                return Err(FrameError::BadHeader("error string over wire cap").into());
            }
            let mut bytes = vec![0u8; len as usize];
            r.read_exact(&mut bytes).map_err(|e| io_err(&e))?;
            Ok(Response::Error(String::from_utf8_lossy(&bytes).into_owned()))
        }
        KIND_STATS => {
            let count = get_u32(r)?;
            if count > 1 << 20 {
                return Err(FrameError::BadHeader("stats count over wire cap").into());
            }
            let mut stats = Vec::with_capacity(count as usize);
            for _ in 0..count {
                stats.push(CohortStats {
                    cohort: get_u64(r)?,
                    rounds_completed: get_u64(r)?,
                    rounds_partial: get_u64(r)?,
                    reports: get_u64(r)?,
                    bits_in: get_u64(r)?,
                    bits_out: get_u64(r)?,
                    open_rounds: get_u32(r)?,
                    shed: get_u64(r)?,
                    quarantined: get_u64(r)?,
                    resident_bytes: get_u64(r)?,
                });
            }
            Ok(Response::Stats(stats))
        }
        KIND_OK => Ok(Response::Ok),
        KIND_BUSY => Ok(Response::Busy {
            retry_after_ms: get_u64(r)?,
        }),
        _ => Err(FrameError::BadHeader("unknown response kind").into()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn report() -> Request {
        Request::Report {
            cohort: 77,
            round: 3,
            client: 2,
            spec: CohortSpec {
                n: 8,
                d: 16,
                spec: CodecSpec::Rlq { q: 32 },
                y: 4.5,
                seed: 0xABCD,
            },
            deadline_ms: 250,
            msg: Message {
                bytes: vec![1, 2, 3, 4, 5],
                bits: 37,
            },
        }
    }

    #[test]
    fn request_roundtrip_all_kinds() {
        for req in [report(), Request::Health, Request::Shutdown] {
            let mut wire = Vec::new();
            write_request(&mut wire, &req).unwrap();
            let got = read_request(&mut Cursor::new(wire)).unwrap();
            assert_eq!(got, req);
        }
    }

    #[test]
    fn response_roundtrip_all_kinds() {
        let responses = [
            Response::Estimate {
                received: 3,
                expected: 8,
                partial: true,
                estimate: vec![1.5, -2.25, 0.0, f64::MIN_POSITIVE],
            },
            Response::Error("spec mismatch".into()),
            Response::Stats(vec![CohortStats {
                cohort: 4,
                rounds_completed: 10,
                rounds_partial: 2,
                reports: 71,
                bits_in: 12345,
                bits_out: 64 * 16 * 10,
                open_rounds: 1,
                shed: 5,
                quarantined: 2,
                resident_bytes: 256,
            }]),
            Response::Ok,
            Response::Busy { retry_after_ms: 120 },
        ];
        for resp in responses {
            let mut wire = Vec::new();
            write_response(&mut wire, &resp).unwrap();
            let got = read_response(&mut Cursor::new(wire)).unwrap();
            assert_eq!(got, resp);
        }
    }

    #[test]
    fn all_codec_specs_survive_the_wire() {
        let specs = [
            CodecSpec::Lq { q: 7 },
            CodecSpec::Rlq { q: 9 },
            CodecSpec::LqHull { q: 3 },
            CodecSpec::D4 { q: 5 },
            CodecSpec::QsgdL2 { q: 15 },
            CodecSpec::QsgdLinf { q: 31 },
            CodecSpec::Hadamard { q: 63 },
            CodecSpec::Vqsgd { reps: 11 },
            CodecSpec::EfSign,
            CodecSpec::PowerSgd { rank: 4 },
            CodecSpec::TernGrad,
            CodecSpec::TopK { k: 100 },
            CodecSpec::Full,
        ];
        for s in specs {
            let (tag, param) = spec_to_wire(s);
            assert_eq!(spec_from_wire(tag, param).unwrap(), s);
        }
        assert!(spec_from_wire(200, 0).is_err());
    }

    #[test]
    fn corrupt_records_rejected_not_panicked() {
        // Wrong magic.
        let mut wire = Vec::new();
        write_request(&mut wire, &Request::Health).unwrap();
        wire[0] ^= 0xFF;
        match read_request(&mut Cursor::new(wire)) {
            Err(TransportError::BadFrame(FrameError::BadMagic { want, .. })) => {
                assert_eq!(want, REQ_MAGIC)
            }
            other => panic!("expected BadMagic, got {other:?}"),
        }
        // Truncated mid-header.
        let mut wire = Vec::new();
        write_request(&mut wire, &report()).unwrap();
        wire.truncate(17);
        assert!(read_request(&mut Cursor::new(wire)).is_err());
        // Unknown kinds.
        let mut wire = Vec::new();
        put_u32(&mut wire, REQ_MAGIC);
        wire.push(99);
        assert!(read_request(&mut Cursor::new(wire)).is_err());
        let mut wire = Vec::new();
        put_u32(&mut wire, RESP_MAGIC);
        wire.push(99);
        assert!(read_response(&mut Cursor::new(wire)).is_err());
    }
}
