//! Length-prefixed message framing over byte streams.
//!
//! The frame encoding is the [`crate::quant::PacketArena`] wire format,
//! reused *verbatim*: `[bits: u64 LE][len: u32 LE][len bytes]`. A TCP
//! stream carrying a batch of messages is byte-for-byte the arena a
//! batched round stages in memory (pinned by
//! `frame_bytes_match_packet_arena` below), so the in-process batch
//! plane and the socket plane share one wire format.
//!
//! The byte length is stored explicitly rather than derived from `bits`
//! because side-float codecs can have `bytes.len() > ceil(bits / 8)`;
//! the well-formedness invariant the reader *does* enforce is the
//! [`crate::quant::Message`] contract `bits <= 8 * len`. Violations —
//! along with oversized length prefixes and streams that end mid-frame —
//! are rejected with a typed [`FrameError`] rather than trusted, since a
//! desynchronized stream would otherwise misparse payload bytes as
//! prefixes indefinitely.

use super::error::{FrameError, TransportError};
use crate::quant::Message;
use std::io::{self, Read, Write};

/// Bytes of frame prefix: bits (u64 LE) + byte length (u32 LE).
pub const PREFIX_BYTES: usize = 8 + 4;

/// Default cap on a single frame's payload length (64 MiB). A `d = 10⁶`
/// full-precision vector is 8 MB, so this clears every realistic round
/// while still refusing attacker-chosen multi-GiB allocations.
pub const MAX_FRAME_BYTES: u32 = 64 << 20;

/// Write one message as a frame. The frame bytes are exactly what
/// [`crate::quant::PacketArena::push`] appends for the same message.
pub fn write_frame<W: Write>(w: &mut W, msg: &Message) -> io::Result<()> {
    let len = u32::try_from(msg.bytes.len()).expect("packet under 4 GiB");
    let mut buf = Vec::with_capacity(PREFIX_BYTES + msg.bytes.len());
    buf.extend_from_slice(&msg.bits.to_le_bytes());
    buf.extend_from_slice(&len.to_le_bytes());
    buf.extend_from_slice(&msg.bytes);
    w.write_all(&buf)
}

/// Read one frame. Returns `Ok(None)` on a clean end-of-stream (the
/// peer closed *between* frames); a stream that ends inside a frame is
/// a [`FrameError::ShortRead`].
pub fn read_frame<R: Read>(r: &mut R, max_len: u32) -> Result<Option<Message>, TransportError> {
    let mut prefix = [0u8; PREFIX_BYTES];
    if !read_exact_or_eof(r, &mut prefix)? {
        return Ok(None);
    }
    let bits = u64::from_le_bytes(prefix[0..8].try_into().unwrap());
    let len = u32::from_le_bytes(prefix[8..12].try_into().unwrap());
    if len > max_len {
        return Err(FrameError::TooLarge { len, max: max_len }.into());
    }
    if bits > 8 * u64::from(len) {
        return Err(FrameError::BitsExceedBytes { bits, len }.into());
    }
    let mut bytes = vec![0u8; len as usize];
    read_exact_all(r, &mut bytes)?;
    Ok(Some(Message { bytes, bits }))
}

/// Fill `buf` from the reader. `Ok(false)` if the stream was already at
/// EOF (zero bytes available); `ShortRead` if it ends partway through.
fn read_exact_or_eof<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<bool, TransportError> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(false);
                }
                return Err(FrameError::ShortRead {
                    needed: buf.len(),
                    got,
                }
                .into());
            }
            Ok(k) => got += k,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(TransportError::from_io(&e)),
        }
    }
    Ok(true)
}

/// Fill `buf`, treating EOF anywhere as a `ShortRead`.
fn read_exact_all<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<(), TransportError> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                return Err(FrameError::ShortRead {
                    needed: buf.len(),
                    got,
                }
                .into())
            }
            Ok(k) => got += k,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(TransportError::from_io(&e)),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::PacketArena;
    use std::io::Cursor;

    fn msg(bytes: Vec<u8>, bits: u64) -> Message {
        Message { bytes, bits }
    }

    #[test]
    fn roundtrip_including_misaligned_and_empty() {
        let msgs = [
            msg(vec![0xAB, 0xCD, 0xEF], 23),
            msg(Vec::new(), 0),
            msg((0..67).collect(), 67 * 8),
        ];
        let mut wire = Vec::new();
        for m in &msgs {
            write_frame(&mut wire, m).unwrap();
        }
        let mut r = Cursor::new(wire);
        for m in &msgs {
            let got = read_frame(&mut r, MAX_FRAME_BYTES).unwrap().unwrap();
            assert_eq!(&got, m);
        }
        assert!(read_frame(&mut r, MAX_FRAME_BYTES).unwrap().is_none());
    }

    /// The stream format IS the arena format: framing the same messages
    /// yields byte-identical buffers, and the frame reader parses an
    /// arena's raw bytes.
    #[test]
    fn frame_bytes_match_packet_arena() {
        let msgs = [msg(vec![9, 8, 7, 6, 5], 33), msg(vec![0xFF], 3)];
        let mut arena = PacketArena::new();
        let mut wire = Vec::new();
        for m in &msgs {
            arena.push(m);
            write_frame(&mut wire, m).unwrap();
        }
        assert_eq!(arena.as_bytes(), &wire[..]);
        let mut r = Cursor::new(arena.as_bytes().to_vec());
        for m in &msgs {
            assert_eq!(&read_frame(&mut r, MAX_FRAME_BYTES).unwrap().unwrap(), m);
        }
    }

    #[test]
    fn short_read_mid_prefix_and_mid_payload_rejected() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &msg(vec![1, 2, 3, 4], 32)).unwrap();
        // Truncate inside the prefix.
        let mut r = Cursor::new(wire[..5].to_vec());
        match read_frame(&mut r, MAX_FRAME_BYTES) {
            Err(TransportError::BadFrame(FrameError::ShortRead { needed, got })) => {
                assert_eq!(needed, PREFIX_BYTES);
                assert_eq!(got, 5);
            }
            other => panic!("expected ShortRead, got {other:?}"),
        }
        // Truncate inside the payload.
        let mut r = Cursor::new(wire[..PREFIX_BYTES + 2].to_vec());
        match read_frame(&mut r, MAX_FRAME_BYTES) {
            Err(TransportError::BadFrame(FrameError::ShortRead { needed, got })) => {
                assert_eq!(needed, 4);
                assert_eq!(got, 2);
            }
            other => panic!("expected ShortRead, got {other:?}"),
        }
    }

    #[test]
    fn oversized_and_inconsistent_prefixes_rejected() {
        // len > max
        let mut wire = Vec::new();
        wire.extend_from_slice(&0u64.to_le_bytes());
        wire.extend_from_slice(&(MAX_FRAME_BYTES + 1).to_le_bytes());
        match read_frame(&mut Cursor::new(wire), MAX_FRAME_BYTES) {
            Err(TransportError::BadFrame(FrameError::TooLarge { len, .. })) => {
                assert_eq!(len, MAX_FRAME_BYTES + 1)
            }
            other => panic!("expected TooLarge, got {other:?}"),
        }
        // bits > 8·len (violates the Message contract)
        let mut wire = Vec::new();
        wire.extend_from_slice(&25u64.to_le_bytes());
        wire.extend_from_slice(&3u32.to_le_bytes());
        wire.extend_from_slice(&[0, 0, 0]);
        match read_frame(&mut Cursor::new(wire), MAX_FRAME_BYTES) {
            Err(TransportError::BadFrame(FrameError::BitsExceedBytes { bits, len })) => {
                assert_eq!((bits, len), (25, 3));
            }
            other => panic!("expected BitsExceedBytes, got {other:?}"),
        }
    }
}
