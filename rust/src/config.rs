//! Minimal configuration layer: a self-contained JSON subset parser/printer
//! (the offline build has no `serde`) plus typed experiment configuration.
//!
//! The JSON implementation supports objects, arrays, strings, numbers,
//! booleans and null — everything emitted by `python/compile/aot.py`'s
//! manifest and by our own experiment config files.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value (subset; numbers are f64).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }
    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(c) => {
                    // copy one UTF-8 scalar
                    let len = utf8_len(c);
                    let slice = &self.bytes[self.pos..self.pos + len];
                    out.push_str(std::str::from_utf8(slice).map_err(|_| self.err("bad utf8"))?);
                    self.pos += len;
                }
            }
        }
    }
    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }
    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Common run configuration shared by the experiment drivers.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Number of worker machines.
    pub n_machines: usize,
    /// Input dimension.
    pub dim: usize,
    /// Quantization levels (`q` in the paper).
    pub q: u32,
    /// Random seed.
    pub seed: u64,
    /// Number of iterations / steps.
    pub iters: usize,
    /// Learning rate (where applicable).
    pub lr: f64,
    /// Number of samples in the generated dataset.
    pub samples: usize,
    /// Multiplier applied to measured distances when estimating `y`.
    pub y_slack: f64,
    /// Session topology: `star`, `tree`, `tree:<m>`, or `both`
    /// (CLI `dme me`/`dme vr`; parsed by
    /// [`crate::coordinator::Topology::parse`]).
    pub topology: String,
    /// `dme vr`: use the error-detecting Algorithm 6 instead of the
    /// Chebyshev reduction.
    pub robust: bool,
    /// Batched-round width (`dme me`/`dme vr`/`dme exp`): run this many
    /// rounds as slots of one `round_batch` call — one worker channel
    /// crossing per batch instead of per round. 1 = sequential rounds.
    pub batch: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            n_machines: 2,
            dim: 100,
            q: 8,
            seed: 0,
            iters: 100,
            lr: 0.8,
            samples: 8192,
            y_slack: 1.5,
            topology: "both".to_string(),
            robust: true,
            batch: 1,
        }
    }
}

impl RunConfig {
    /// Apply `key=value` overrides (CLI style).
    pub fn apply(&mut self, key: &str, value: &str) -> Result<(), String> {
        macro_rules! parse {
            () => {
                value
                    .parse()
                    .map_err(|_| format!("bad value '{value}' for {key}"))?
            };
        }
        match key {
            "n" | "machines" => self.n_machines = parse!(),
            "d" | "dim" => self.dim = parse!(),
            "q" => self.q = parse!(),
            "seed" => self.seed = parse!(),
            "iters" => self.iters = parse!(),
            "lr" => self.lr = parse!(),
            "samples" => self.samples = parse!(),
            "y_slack" => self.y_slack = parse!(),
            "batch" => {
                self.batch = parse!();
                if self.batch == 0 {
                    return Err(format!("bad value '{value}' for batch (must be >= 1)"));
                }
            }
            "topology" => self.topology = value.to_string(),
            "robust" => match value {
                "1" | "true" | "yes" => self.robust = true,
                "0" | "false" | "no" => self.robust = false,
                _ => return Err(format!("bad value '{value}' for robust (0|1)")),
            },
            _ => return Err(format!("unknown config key '{key}'")),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let src = r#"{"a": [1, 2.5, -3], "b": "hi\n", "c": {"d": true, "e": null}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "hi\n");
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_bool(), Some(true));
        let printed = v.to_string();
        let v2 = Json::parse(&printed).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{\"a\":1} extra").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e2").unwrap().as_f64(), Some(-150.0));
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
    }

    #[test]
    fn config_overrides() {
        let mut c = RunConfig::default();
        c.apply("n", "16").unwrap();
        c.apply("q", "64").unwrap();
        assert_eq!(c.n_machines, 16);
        assert_eq!(c.q, 64);
        assert!(c.apply("bogus", "1").is_err());
        assert!(c.apply("n", "xyz").is_err());
    }

    #[test]
    fn batch_key() {
        let mut c = RunConfig::default();
        assert_eq!(c.batch, 1);
        c.apply("batch", "64").unwrap();
        assert_eq!(c.batch, 64);
        assert!(c.apply("batch", "0").is_err());
        assert!(c.apply("batch", "x").is_err());
    }

    #[test]
    fn topology_and_robust_keys() {
        let mut c = RunConfig::default();
        assert_eq!(c.topology, "both");
        assert!(c.robust);
        c.apply("topology", "tree:4").unwrap();
        c.apply("robust", "0").unwrap();
        assert_eq!(c.topology, "tree:4");
        assert!(!c.robust);
        assert!(c.apply("robust", "maybe").is_err());
    }
}
