//! # dme — Lattice-based Distributed Mean Estimation and Variance Reduction
//!
//! Reproduction of *"New Bounds For Distributed Mean Estimation and Variance
//! Reduction"* (Davies, Gurunathan, Moshrefi, Ashkboos, Alistarh — ICLR 2021)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1 (Pallas, build time)** — the quantization hot-spot (cubic
//!   lattice encode/decode, fast Walsh–Hadamard transform) as Pallas kernels,
//!   validated against a pure-`jnp` oracle.
//! * **Layer 2 (JAX, build time)** — compute graphs (least-squares batch
//!   gradients, power-iteration updates, MLP training steps, fused
//!   rotate+encode pipelines) lowered once to HLO text by
//!   `python/compile/aot.py`.
//! * **Layer 3 (Rust, run time)** — this crate: the distributed coordinator
//!   (star / binary-tree topologies with exact bit accounting), the full
//!   quantization library (including every baseline the paper compares
//!   against), and the PJRT runtime that loads the AOT artifacts. Python is
//!   never on the request path.
//!
//! The primary entry point is the **session API**: a
//! [`coordinator::DmeBuilder`] configures the cluster shape, topology,
//! codec and `y` policy once, and the [`coordinator::DmeSession`] it
//! builds keeps the machine threads alive across rounds — the paper's §9
//! deployment pattern (thousands of rounds inside an optimizer loop),
//! with per-round buffers recycled through
//! [`quant::VectorCodec::encode_into`] / `decode_into` scratch space:
//!
//! ```
//! use dme::coordinator::{CodecSpec, DmeBuilder, Topology};
//!
//! let n = 4;
//! let d = 16;
//! let inputs: Vec<Vec<f64>> = (0..n)
//!     .map(|i| vec![10.0 + 0.01 * i as f64; d])
//!     .collect();
//! let mut session = DmeBuilder::new(n, d)
//!     .topology(Topology::Star) // or Topology::Tree { m: n }
//!     .codec(CodecSpec::Lq { q: 16 })
//!     .seed(7)
//!     .build();
//! for _ in 0..3 {
//!     let out = session.round_with_y(&inputs, 1.0);
//!     assert!(out.agreement, "all machines output the same vector");
//! }
//! ```
//!
//! The historical one-shot free functions (`mean_estimation_star`,
//! `mean_estimation_tree`, `robust_variance_reduction`, …) remain as thin
//! wrappers over one-round sessions, bit-identical for the same
//! `(seed, round)`.
//!
//! The public API is organized as:
//!
//! * [`quant`] — quantizers: `LatticeQuantizer` (LQSGD), `RotatedLattice`
//!   (RLQSGD), robust/error-detecting agreement, the sublinear scheme, and
//!   baselines (QSGD, Suresh–Hadamard, vQSGD, EF-SignSGD, PowerSGD, TernGrad,
//!   Top-K).
//! * [`coordinator`] — the `DmeBuilder`/`DmeSession` API and the paper's
//!   algorithms 3–6 over a simulated message-passing cluster.
//! * [`sim`] — the in-process distributed substrate (threads + channels with
//!   exact per-machine bit metering).
//! * [`net`] — the pluggable transport layer: the `Transport` /
//!   `TransportEndpoint` traits both [`sim`] and the TCP mesh implement,
//!   length-prefixed wire framing (the `PacketArena` format verbatim),
//!   and the multi-cohort DME service front-end (`dme serve` /
//!   `dme report`).
//! * [`store`] — the service's durability layer: checksummed write-ahead
//!   log, spill-to-disk partial-aggregate runs, and crash recovery that
//!   replays a killed leader back to bit-identical estimates
//!   (`dme serve data_dir=…`).
//! * [`runtime`] — PJRT client wrapper loading `artifacts/*.hlo.txt`
//!   (feature `pjrt`; a stub otherwise).
//! * [`data`], [`opt`] — workload substrates (datasets, SGD/local-SGD/power
//!   iteration drivers, all consuming the session API).
//! * [`exp`] — the benchmark harness regenerating every figure and table of
//!   the paper's Section 9.

// Style posture for `clippy -D warnings` in CI: the offline substrate is
// written with explicit index loops and ceil-divisions where they read
// closer to the paper's pseudocode; keep those patterns allowed.
#![allow(
    clippy::needless_range_loop,
    clippy::manual_div_ceil,
    clippy::too_many_arguments,
    clippy::type_complexity
)]

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod linalg;
pub mod net;
pub mod opt;
pub mod pool;
pub mod quant;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod simd;
pub mod store;
