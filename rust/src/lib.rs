//! # dme — Lattice-based Distributed Mean Estimation and Variance Reduction
//!
//! Reproduction of *"New Bounds For Distributed Mean Estimation and Variance
//! Reduction"* (Davies, Gurunathan, Moshrefi, Ashkboos, Alistarh — ICLR 2021)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1 (Pallas, build time)** — the quantization hot-spot (cubic
//!   lattice encode/decode, fast Walsh–Hadamard transform) as Pallas kernels,
//!   validated against a pure-`jnp` oracle.
//! * **Layer 2 (JAX, build time)** — compute graphs (least-squares batch
//!   gradients, power-iteration updates, MLP training steps, fused
//!   rotate+encode pipelines) lowered once to HLO text by
//!   `python/compile/aot.py`.
//! * **Layer 3 (Rust, run time)** — this crate: the distributed coordinator
//!   (star / binary-tree topologies with exact bit accounting), the full
//!   quantization library (including every baseline the paper compares
//!   against), and the PJRT runtime that loads the AOT artifacts. Python is
//!   never on the request path.
//!
//! The public API is organized as:
//!
//! * [`quant`] — quantizers: `LatticeQuantizer` (LQSGD), `RotatedLattice`
//!   (RLQSGD), robust/error-detecting agreement, the sublinear scheme, and
//!   baselines (QSGD, Suresh–Hadamard, vQSGD, EF-SignSGD, PowerSGD, TernGrad,
//!   Top-K).
//! * [`coordinator`] — the paper's algorithms 3–6 over a simulated
//!   message-passing cluster.
//! * [`sim`] — the in-process distributed substrate (threads + channels with
//!   exact per-machine bit metering).
//! * [`runtime`] — PJRT client wrapper loading `artifacts/*.hlo.txt`.
//! * [`data`], [`opt`] — workload substrates (datasets, SGD/local-SGD/power
//!   iteration drivers).
//! * [`exp`] — the benchmark harness regenerating every figure and table of
//!   the paper's Section 9.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod linalg;
pub mod opt;
pub mod quant;
pub mod rng;
pub mod runtime;
pub mod sim;
