//! Local SGD with compressed model deltas (Experiment 6 as a program):
//! four workers train locally and average every 10 steps; the deltas are
//! compressed with RLQSGD vs QSGD at the same bit budget.
//!
//! Run: `cargo run --release --example local_sgd`

use dme::coordinator::CodecSpec;
use dme::data::gen_lsq;
use dme::opt::local_sgd::{run_local_sgd, LocalSgdConfig};

fn main() {
    let ds = gen_lsq(8192, 100, 11);
    let cfg = LocalSgdConfig {
        n_machines: 4,
        lr: 0.02,
        local_steps: 10,
        rounds: 40,
        batch: 256,
        seed: 0,
        y0: 0.5,
        ..Default::default()
    };

    println!("Local SGD: 4 workers, avg every 10 steps, S=8192 d=100\n");
    println!(
        "{:<16} {:>14} {:>14} {:>16}",
        "method", "final loss", "mean quant err", "max bits/round"
    );
    for (label, spec) in [
        ("uncompressed", None),
        ("RLQSGD(q=16)", Some(CodecSpec::Rlq { q: 16 })),
        ("LQSGD(q=16)", Some(CodecSpec::Lq { q: 16 })),
        ("QSGD-L2(q=16)", Some(CodecSpec::QsgdL2 { q: 16 })),
        ("Hadamard(q=16)", Some(CodecSpec::Hadamard { q: 16 })),
    ] {
        let t = run_local_sgd(&ds, spec, &cfg);
        let qerr = t.quant_err.iter().sum::<f64>() / t.quant_err.len() as f64;
        println!(
            "{:<16} {:>14.4e} {:>14.4e} {:>16}",
            label,
            t.loss.last().unwrap(),
            qerr,
            t.max_bits_sent.iter().max().unwrap()
        );
    }
    println!("\nexpected shape (paper Fig 11): lattice methods reach lower loss and");
    println!("an order-of-magnitude smaller quantization error than norm-based ones.");
}
