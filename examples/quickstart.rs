//! Quickstart: the library's public API in five minutes.
//!
//! 1. Pairwise lattice quantization (Theorem 1's encode/decode contract).
//! 2. MeanEstimation over a simulated 8-machine cluster, star and tree.
//! 3. Robust (error-detecting) VarianceReduction.
//! 4. The session API (`DmeBuilder` → `DmeSession`) — the primary entry
//!    point: one persistent cluster driven for many rounds, as in an SGD
//!    deployment (§9). The leader aggregates by *streaming fold*: each
//!    arriving packet is decode-accumulated straight into an O(d) sum
//!    (`VectorCodec::decode_accumulate_into`), so leader memory does not
//!    grow with the cluster.
//! 5. The fold kernels stand-alone (`coordinator::fold`): sequential and
//!    chunk-sharded parallel aggregation of pre-collected messages.
//! 6. The vectorized encode plane (`quant::encode_chunked`,
//!    `BitWriter::push_block`): the write-side twin of (5) — block
//!    kernels behind `encode_into` plus a chunk-parallel encode for huge
//!    gradients, all bit-identical to the scalar encode.
//! 7. Batched rounds (`DmeSession::round_batch_with_y`): ship many
//!    vectors — e.g. every layer gradient of an SGD step — as slots of
//!    one batched round: a single command/response crossing per worker,
//!    uploads staged in a pooled packet arena, per-slot results
//!    bit-identical to sequential rounds.
//! 8. A baseline comparison on the fast path: the comparator codecs
//!    (here QSGD-L2) ride the same blocked kernels as the lattice
//!    family — fused `encode_into`, chunk-parallel `encode_chunked`,
//!    streaming and chunk-sharded folds — so head-to-head sweeps cost
//!    comparator wall-clock proportional to the wire bits, not the
//!    seed's scalar loops.
//! 9. SIMD lanes and the persistent worker pool (`simd`, `pool`): the
//!    explicit-lane kernels behind the blocked data plane. Compile with
//!    `--features simd` to dispatch the FWHT butterflies, stochastic
//!    rounding, bulk RNG fill, and bit packing to AVX2 at runtime —
//!    every kernel keeps an always-compiled scalar twin and the outputs
//!    are bit-identical either way, so the feature changes wall-clock,
//!    never a wire bit. The chunk kernels of (5)/(6) run on one
//!    process-wide pool of parked worker threads, spawned once.
//! 10. Serving cohorts over TCP (`net::service`): a leader-side loop
//!    multiplexing many independent client groups over real sockets —
//!    each report is folded straight into the cohort's O(d) accumulator,
//!    a full round answers every client with the identical estimate, and
//!    a deadline closes a short round over the k ≤ n arrived reports
//!    with the mean renormalized by 1/k. The `dme serve` / `dme report`
//!    subcommands wrap exactly this API.
//! 11. In-round fault tolerance (`net::faulty` + `DmeSession::round_partial`):
//!    wrap the session's transport in a seeded fault-injection layer and
//!    run k-of-n partial rounds under a `StragglerPolicy` — dropped
//!    machines cost accuracy (the 1/k-renormalized partial mean), never
//!    a hang or a panic; an under-quorum round fails with a *typed*
//!    `QuorumFailed` and the session keeps serving. One `FaultPlan` seed
//!    reproduces the whole fault schedule.
//! 12. Durable crash recovery (`store` + `CohortTable::durable`): the
//!    cohort leader WALs every accepted report before folding it, so a
//!    leader killed mid-round and restarted on the same data dir
//!    replays the log into the *bit-identical* fold an uninterrupted
//!    leader produces — demonstrated by dropping the table with a round
//!    open and finishing that round after recovery. `dme serve
//!    data_dir=DIR sync=always` wraps the same store.
//! 13. Overload hardening & report screening (`net::screen`): the same
//!    cohort table with the service edge's defenses on — every report
//!    is validated before it touches the WAL or the fold (frame-size
//!    coherence, NaN/Inf hygiene, the distance filter), a screened-out
//!    report is *bit-invisible* to the estimate, and honest rounds are
//!    bit-identical to `screen=off`. `dme serve screen=distance …`
//!    wires the same knobs to TCP; `dme exp chaos` replays a seeded
//!    hostile workload against it.
//!
//! Run: `cargo run --release --example quickstart`

use dme::coordinator::{
    mean_estimation_star, mean_estimation_tree, robust_variance_reduction, CodecSpec, DmeBuilder,
    Topology, YPolicy,
};
use dme::linalg::{dist2, dist_inf, mean_vecs};
use dme::quant::{LatticeQuantizer, VectorCodec};
use dme::rng::Rng;
use dme::sim::summarize;

fn main() {
    // ---------------------------------------------------------------
    // 1. Pairwise quantization: u sends a 64-dim vector to v using
    //    d·log2(q) = 64·4 = 256 bits; v decodes with its own vector.
    // ---------------------------------------------------------------
    let d = 64;
    let q = 16;
    let y = 1.0; // known bound on ‖x_u − x_v‖∞
    let mut shared = Rng::new(42); // shared randomness (both parties)
    let mut rng = Rng::new(7);

    let x_u: Vec<f64> = (0..d).map(|_| 1000.0 + rng.uniform(-0.4, 0.4)).collect();
    let x_v: Vec<f64> = x_u.iter().map(|v| v + rng.uniform(-0.5, 0.5)).collect();

    let mut codec = LatticeQuantizer::from_y(d, q, y, &mut shared);
    let msg = codec.encode(&x_u, &mut rng);
    let decoded = codec.decode(&msg, &x_v);
    println!("== pairwise quantization ==");
    println!("bits sent        : {} ({} per coordinate)", msg.bits, msg.bits / d as u64);
    println!("‖decoded − x_u‖∞ : {:.4} (≤ s/2 = {:.4})", dist_inf(&decoded, &x_u), codec.lattice.s / 2.0);
    println!("note: inputs live near 1000 — error depends only on their distance.\n");

    // ---------------------------------------------------------------
    // 2. MeanEstimation across 8 machines (inputs within y of each other).
    // ---------------------------------------------------------------
    let n = 8;
    let inputs: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..d).map(|_| 1000.0 + rng.uniform(-0.5, 0.5)).collect())
        .collect();
    let mu = mean_vecs(&inputs);

    let star = mean_estimation_star(&inputs, &CodecSpec::Lq { q }, y, 1, 0);
    let t = summarize(&star.traffic);
    println!("== mean estimation, star topology (Algorithm 3) ==");
    println!("‖EST − μ‖²  : {:.3e}", dist2(star.estimate(), &mu).powi(2));
    println!("max bits/machine (sent): {} — leader pays O(nd log q), workers O(d log q)", t.max_sent);

    let tree = mean_estimation_tree(&inputs, n, y, 1, 0);
    let t = summarize(&tree.traffic);
    println!("== mean estimation, tree topology (Algorithm 4) ==");
    println!("‖EST − μ‖²  : {:.3e}", dist2(tree.estimate(), &mu).powi(2));
    println!("max bits/machine (sent): {} — worst-case O(d log q) for everyone\n", t.max_sent);

    // ---------------------------------------------------------------
    // 3. Robust VarianceReduction: one machine's input is wild; error
    //    detection escalates its exchange instead of corrupting the mean.
    // ---------------------------------------------------------------
    let sigma = 0.5;
    let nabla: Vec<f64> = (0..d).map(|_| 1000.0 + rng.next_gaussian()).collect();
    let mut vr_inputs: Vec<Vec<f64>> = (0..n)
        .map(|_| {
            nabla
                .iter()
                .map(|v| v + sigma / (d as f64).sqrt() * rng.next_gaussian())
                .collect()
        })
        .collect();
    for v in vr_inputs[5].iter_mut() {
        *v += 40.0; // a heavy-tailed outlier
    }
    let out = robust_variance_reduction(&vr_inputs, sigma, 16, 2, 0);
    println!("== robust variance reduction (Algorithm 6) ==");
    println!("input  ‖x₀ − ∇‖² : {:.3e}", dist2(&vr_inputs[0], &nabla).powi(2));
    println!("output ‖EST − ∇‖²: {:.3e}", dist2(&out.estimate, &nabla).powi(2));
    println!("escalation rounds per worker (stage 1): {:?}", out.rounds_stage1);
    println!("(the outlier machine used extra rounds; everyone else paid the base cost)\n");

    // ---------------------------------------------------------------
    // 4. The session API: configure once, round many times. The cluster
    //    threads stay alive, every per-machine buffer is recycled, and
    //    the leader folds each incoming bitstream straight into its O(d)
    //    accumulator (streaming fold — no O(n·d) decoded set), so a
    //    steady-state round allocates O(1) vectors — this is how the
    //    optimizer drivers (opt::dist_gd etc.) consume the protocols.
    //    Turning `.diagnostics(true)` on switches the leader to the
    //    collecting path and surfaces `decoded_at_leader`.
    // ---------------------------------------------------------------
    let mut session = DmeBuilder::new(n, d)
        .topology(Topology::Star) // or Topology::Tree { m: n }
        .codec(CodecSpec::Lq { q })
        .y0(1.0)
        .y_policy(YPolicy::FromQuantized { slack: 1.5 }) // §9.2 zero-cost y maintenance
        .seed(42)
        .build();
    println!("== persistent session (DmeBuilder → DmeSession) ==");
    for round in 0..3 {
        let out = session.round(&inputs);
        println!(
            "round {round}: leader={:?} agree={} ‖EST − μ‖²={:.3e} y={:.3} cum max_sent={}b",
            out.leader,
            out.agreement,
            dist2(&out.estimate, &mu).powi(2),
            out.y_used,
            out.traffic.max_sent,
        );
    }
    println!("(same protocol bits as the one-shot calls above — minus the per-round thread spawns)\n");

    // ---------------------------------------------------------------
    // 5. The fold kernels directly: aggregate a batch of already-
    //    collected messages. `fold_mean` is the sequential fused fold;
    //    `fold_mean_chunked` shards d across threads — both bit-identical
    //    to decode-then-sum.
    // ---------------------------------------------------------------
    use dme::coordinator::{fold_mean, fold_mean_chunked, FoldPart};
    let mut lq = LatticeQuantizer::from_y(d, q, y, &mut Rng::new(42));
    let reference = inputs[0].clone();
    let msgs: Vec<_> = inputs[1..]
        .iter()
        .map(|x| {
            let mut m = dme::quant::Message::empty();
            lq.encode_into(x, &mut rng, &mut m);
            m
        })
        .collect();
    let mut parts = vec![FoldPart::Own(&inputs[0])];
    parts.extend(msgs.iter().map(FoldPart::Encoded));
    let mut seq = vec![0.0; d];
    fold_mean(&lq, &parts, &reference, &mut seq);
    let mut par = vec![0.0; d];
    fold_mean_chunked(&lq, &parts, &reference, &mut par, 1024);
    println!("== streaming fold kernels (coordinator::fold) ==");
    println!("‖fold − μ‖∞        : {:.4}", dist_inf(&seq, &mu));
    println!("chunk-sharded == sequential: {}\n", seq == par);

    // ---------------------------------------------------------------
    // 6. The fast encode path. `encode_into` already runs the fused
    //    block kernels (round → mask-color → one packed word store per
    //    ⌊64/width⌋ colors via BitWriter::push_block); for a huge
    //    gradient, `encode_chunked` additionally shards the pack across
    //    cores at byte-aligned chunk boundaries. Every variant produces
    //    the identical wire message — vectorization never moves a bit.
    // ---------------------------------------------------------------
    let big_d = 1 << 16;
    let grad: Vec<f64> = (0..big_d).map(|i| (i as f64 * 0.001).sin()).collect();
    let mut big_lq = LatticeQuantizer::from_y(big_d, q, y, &mut Rng::new(9));
    let mut seq_msg = dme::quant::Message::empty();
    big_lq.encode_into(&grad, &mut rng, &mut seq_msg); // fused block kernel
    let mut par_msg = dme::quant::Message::empty();
    dme::quant::encode_chunked(&mut big_lq, &grad, &mut rng, &mut par_msg, 8192); // cores
    println!("== vectorized encode plane (quant::encode_chunked) ==");
    println!("gradient dims      : {big_d} → {} wire bits", seq_msg.bits);
    println!("chunk-parallel == sequential encode: {}\n", par_msg == seq_msg);

    // ---------------------------------------------------------------
    // 7. Batched per-layer SGD rounds. An SGD step ships one gradient
    //    *per layer* — here four layers of very different widths — and
    //    the batched control plane exchanges all of them in ONE
    //    command/response crossing per worker: uploads are pre-encoded
    //    back-to-back into a pooled packet arena, per-slot shared
    //    randomness comes from one fan-out, and every slot is
    //    bit-identical to the sequential round at the same index
    //    (pinned by rust/tests/session_parity.rs). This is how
    //    opt::mlp::train_distributed aggregates its layers.
    // ---------------------------------------------------------------
    let layer_dims = [512usize, 64, 256, 4]; // w1, b1, w2, b2
    let slots: Vec<Vec<Vec<f64>>> = layer_dims
        .iter()
        .map(|&dl| {
            (0..n)
                .map(|_| (0..dl).map(|_| 0.3 + rng.uniform(-0.2, 0.2)).collect())
                .collect()
        })
        .collect();
    let ys = [1.0, 1.0, 1.0, 1.0]; // per-layer distance bounds
    let mut batched = DmeBuilder::new(n, 512).codec(CodecSpec::Lq { q }).seed(7).build();
    let outs = batched.round_batch_with_y(&slots, &ys);
    println!("== batched per-layer rounds (DmeSession::round_batch_with_y) ==");
    for (li, o) in outs.iter().enumerate() {
        let mu_l = mean_vecs(&slots[li]);
        println!(
            "layer {li} (d={:>3}): slot round={} leader={:?} agree={} ‖EST − μ‖∞={:.4}",
            layer_dims[li],
            o.round,
            o.leader,
            o.agreement,
            dist_inf(&o.estimate, &mu_l),
        );
    }
    // The batch is pure scheduling: replaying the slots as sequential
    // rounds on a fresh session reproduces every estimate exactly.
    let mut sequential = DmeBuilder::new(n, 512).codec(CodecSpec::Lq { q }).seed(7).build();
    let same = outs.iter().enumerate().all(|(li, o)| {
        sequential.round_with_y(&slots[li], ys[li]).estimate == o.estimate
    });
    println!("batched == sequential rounds, slot for slot: {same}");
    println!("(4 layers, 1 worker crossing — the control-plane cost of a single round)\n");

    // ---------------------------------------------------------------
    // 8. Baselines on the fast path. The paper's experiments measure the
    //    lattice codecs *against* QSGD, the Suresh-Hadamard scheme, etc.
    //    — and those comparators now ride the identical blocked data
    //    plane: a fused block encode fed by one bulk-uniform RNG fill, a
    //    chunk-parallel encode (the byte-aligned header rides the first
    //    chunk), and fused/seekable fold kernels. Same wire bits as the
    //    seed scalar loops, bit for bit — only the wall-clock moved.
    // ---------------------------------------------------------------
    use dme::quant::baselines::{Qsgd, QsgdNorm};
    let mut qsgd = Qsgd::new(big_d, 16, QsgdNorm::L2);
    let mut rng2 = rng.clone(); // replay the same stochastic-rounding draws
    let mut q_seq = dme::quant::Message::empty();
    qsgd.encode_into(&grad, &mut rng, &mut q_seq); // fused block kernel
    let mut q_par = dme::quant::Message::empty();
    dme::quant::encode_chunked(&mut qsgd, &grad, &mut rng2, &mut q_par, 8192);
    // Aggregate a small batch with the chunk-sharded fold (QSGD's
    // fixed-width fields seek straight to each chunk).
    let peers: Vec<dme::quant::Message> = (0..4)
        .map(|_| {
            let mut m = dme::quant::Message::empty();
            qsgd.encode_into(&grad, &mut rng, &mut m);
            m
        })
        .collect();
    let parts: Vec<FoldPart> = peers.iter().map(FoldPart::Encoded).collect();
    let mut folded = vec![0.0; big_d];
    fold_mean_chunked(&qsgd, &parts, &grad, &mut folded, 8192);
    println!("== baseline comparator on the fast path (QSGD-L2, q=16) ==");
    println!(
        "gradient dims      : {big_d} → {} wire bits ({} per coordinate + header)",
        q_seq.bits,
        (q_seq.bits - 64) / big_d as u64
    );
    println!("note: q_par replays q_seq's RNG stream, so the streams match exactly.");
    println!("chunk-parallel == sequential encode: {}", q_par == q_seq);
    println!(
        "chunk-sharded fold of 4 peers done : ‖fold − x‖∞ = {:.4}",
        dist_inf(&folded, &grad)
    );
    println!();

    // ---------------------------------------------------------------
    // 9. SIMD lanes + the persistent worker pool. Everything above
    //    already ran on them: the FWHT butterflies, stochastic rounding,
    //    bulk uniform fills, and 64-bit field packing dispatch to
    //    explicit AVX2 lanes when built with `--features simd` (runtime-
    //    detected, scalar twin otherwise), and the chunk-parallel
    //    encode/fold kernels of (5)/(6) ran on one process-wide pool of
    //    parked workers instead of spawning threads per call. Both are
    //    pure wall-clock: rebuild with/without `simd`, or resize the
    //    pool, and every wire bit and estimate above is unchanged
    //    (pinned by rust/tests/prop.rs).
    //
    //    Build variants:
    //      cargo run --release --example quickstart                  # scalar
    //      cargo run --release --features simd --example quickstart  # AVX2
    // ---------------------------------------------------------------
    println!("== simd lanes + worker pool (quant::simd / pool) ==");
    println!(
        "simd feature compiled: {} | active this run: {} | lanes: {}",
        dme::simd::compiled(),
        dme::simd::active(),
        dme::simd::lanes()
    );
    // The dispatched kernel and its scalar twin are bit-identical:
    let xs: Vec<f64> = (0..33).map(|i| (i as f64 * 0.7).sin() * 1e3).collect();
    let off: Vec<f64> = (0..33).map(|i| (i as f64 * 0.3).cos()).collect();
    let mut a = vec![0.0; 33];
    let mut b = vec![0.0; 33];
    dme::simd::quantize_scaled(&xs, &off, 0.25, &mut a);
    dme::simd::quantize_scaled_scalar(&xs, &off, 0.25, &mut b);
    let same = a.iter().zip(&b).all(|(u, v)| u.to_bits() == v.to_bits());
    println!("dispatched == scalar twin, bit for bit: {same}");
    println!(
        "worker pool: {} chunk workers (spawned once, parked between jobs), {} machine leases live\n",
        dme::pool::ChunkPool::global().size(),
        dme::pool::spawned_workers()
    );

    // ---------------------------------------------------------------
    // 10. Serving cohorts over TCP. One `serve` loop owns the leader
    //    role for every cohort: clients connect, report their encoded
    //    vector for a (cohort, round), and block until the round closes
    //    — either all n reports arrived (full) or the deadline passed
    //    and the k ≤ n arrivals are renormalized by 1/k (partial).
    //    `max_rounds: Some(2)` makes the service exit after our two
    //    rounds, so the example terminates cleanly.
    // ---------------------------------------------------------------
    use dme::net::cohort::CohortSpec;
    use dme::net::service::{report_round, serve, ServeOpts};
    let listener = std::net::TcpListener::bind(("127.0.0.1", 0)).expect("bind loopback");
    let addr = listener.local_addr().expect("bound address").to_string();
    let server = std::thread::spawn(move || {
        serve(
            listener,
            ServeOpts {
                default_deadline_ms: 10_000,
                max_rounds: Some(2),
                ..ServeOpts::default()
            },
        )
    });
    // Every client of a cohort shares the spec: it pins the codec and
    // the shared randomness, and y must bound the clients' vectors in
    // ℓ∞ (the decode reference is the zero vector).
    let cs = CohortSpec {
        n: 3,
        d: 32,
        spec: CodecSpec::Lq { q: 64 },
        y: 8.0,
        seed: 42,
    };
    // Round 0: all three clients report concurrently (each call blocks
    // until the round closes, so they must overlap).
    let timeout = std::time::Duration::from_secs(10);
    let clients: Vec<_> = (0..cs.n)
        .map(|client| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let input = vec![client as f64; cs.d];
                report_round(&addr, 7, 0, client, &cs, &input, 0, timeout)
                    .expect("round 0 estimate")
            })
        })
        .collect();
    let outs: Vec<_> = clients.into_iter().map(|h| h.join().expect("client thread")).collect();
    println!("== serving cohorts over TCP (net::service) ==");
    println!(
        "round 0 (full)   : received={}/{} partial={} mean0={:.3} (true mean 1.0 ± quantization)",
        outs[0].received, outs[0].expected, outs[0].partial, outs[0].estimate[0]
    );
    println!("all clients saw the identical estimate: {}", outs.iter().all(|o| *o == outs[0]));
    // Round 1: only client 0 shows up; its 200 ms deadline closes the
    // round over k=1 of n=3 — the fold renormalizes by 1/k, so the
    // estimate tracks the arrived report, not a third of it.
    let input = vec![5.0; cs.d];
    let out = report_round(&addr, 7, 1, 0, &cs, &input, 200, timeout).expect("round 1 estimate");
    println!(
        "round 1 (dropout): received={}/{} partial={} mean0={:.3} (tracks 5.0 — renormalized)",
        out.received, out.expected, out.partial, out.estimate[0]
    );
    let summary = server.join().expect("server thread").expect("serve exits cleanly");
    println!(
        "service summary  : rounds={} partial={} cohorts={} bits_in={} bits_out={}",
        summary.rounds_completed,
        summary.rounds_partial,
        summary.cohorts,
        summary.traffic.recv_bits,
        summary.traffic.sent_bits
    );
    println!("(`dme serve` / `dme report` drive the same loop from the CLI)");
    println!();

    // ---------------------------------------------------------------
    // 11. In-round fault tolerance. The same session API, but the
    //    transport is wrapped in a seeded fault-injection layer
    //    (`DmeBuilder::fault_plan`): here every machine's sends vanish
    //    in 30% of its rounds, reproducibly from one seed. Partial
    //    rounds (`round_partial`) close at a deadline over the k ≤ n
    //    reports that made it, renormalized by 1/k — exactly the
    //    semantics of §10's short rounds — and report who was dropped.
    //    A round that cannot reach `k_min` fails with a typed error
    //    instead of panicking, and the session stays usable.
    // ---------------------------------------------------------------
    use dme::coordinator::StragglerPolicy;
    use dme::net::faulty::FaultPlan;
    use dme::net::TransportError;
    let mut faulted = DmeBuilder::new(n, d)
        .codec(CodecSpec::Lq { q })
        .seed(42)
        .fault_plan(FaultPlan::dropout(0xFA017, 0.3))
        .build();
    let policy = StragglerPolicy::deterministic(std::time::Duration::from_millis(100), 1, 5);
    println!("== in-round fault tolerance (net::faulty + round_partial) ==");
    for _ in 0..3 {
        let out = faulted.round_partial_with_y(&inputs, y, &policy).expect("quorum of 1");
        println!(
            "round {}: k={}/{} dropped={:?} retries={} ‖EST − μ‖²={:.3e}",
            out.round,
            out.participants,
            n,
            out.dropped,
            out.retries_used,
            dist2(&out.estimate, &mu).powi(2),
        );
    }
    // Demand a quorum the fault schedule cannot deliver: the round
    // fails *detectably* — got/need in the error — and the next round
    // on the same session succeeds.
    let mut doomed = DmeBuilder::new(n, d)
        .codec(CodecSpec::Lq { q })
        .seed(42)
        .fault_plan(FaultPlan::dropout(0xFA017, 1.0))
        .build();
    let strict = StragglerPolicy::deterministic(std::time::Duration::from_millis(60), n, 5);
    match doomed.round_partial_with_y(&inputs, y, &strict) {
        Err(TransportError::QuorumFailed { got, need }) => {
            println!("all-dropped round: QuorumFailed {{ got: {got}, need: {need} }} (typed, no panic)")
        }
        other => println!("unexpected: {other:?}"),
    }
    let lax = StragglerPolicy::deterministic(std::time::Duration::from_millis(60), 1, 5);
    let out = doomed.round_partial_with_y(&inputs, y, &lax).expect("leader's own report");
    println!(
        "same session, k_min=1: k={} (the coordinator's own report) — still serving",
        out.participants
    );
    println!("(`dme exp dropout` sweeps dropout rate × codec with this machinery)");
    println!();

    // ---------------------------------------------------------------
    // 12. Durable crash recovery. The cohort table from (10), but every
    //    accepted report is appended to a checksummed write-ahead log
    //    (and fsynced, with SyncPolicy::Always) *before* it is folded.
    //    Killing the leader mid-round — here: dropping the table, a
    //    process crash without the mess — loses nothing: a table
    //    reopened on the same data dir replays the log into the exact
    //    same streaming fold, and finishing the round yields the
    //    bit-identical estimate an uninterrupted leader produces.
    //    `dme serve data_dir=DIR sync=always` wraps exactly this.
    // ---------------------------------------------------------------
    use dme::net::cohort::{client_encoder_rng, cohort_codec, CohortKey, CohortTable, Submit};
    use dme::store::{DurabilityOpts, SyncPolicy};
    let data_dir = std::env::temp_dir().join(format!("dme-quickstart-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&data_dir);
    let opts = DurabilityOpts {
        sync: SyncPolicy::Always, // fsync every append: smallest crash window
        ..DurabilityOpts::new(&data_dir)
    };
    let key = CohortKey { cohort: 7, round: 2 };
    let report = |client: usize| {
        let x = vec![client as f64; cs.d];
        let mut enc = cohort_codec(&cs, key.round);
        let mut enc_rng = client_encoder_rng(cs.seed, key.round, client);
        enc.encode(&x, &mut enc_rng)
    };
    println!("== durable crash recovery (store + CohortTable::durable) ==");
    {
        let (mut table, _) = CohortTable::durable(&opts).expect("open data dir");
        for client in [0, 1] {
            let sub = table.submit(key, &cs, client, &report(client), 0, 60_000);
            assert!(matches!(sub, Submit::Pending { .. }), "round still waiting");
        }
        println!("2 of {} reports WAL'd and folded — killing the leader now", cs.n);
        // Dropped with the round open: everything the next process
        // needs is already on disk.
    }
    let (mut recovered, rec) = CohortTable::durable(&opts).expect("recover data dir");
    println!(
        "recovery: {} reports replayed, {} round reopened, tail truncated: {}",
        rec.reports_replayed,
        rec.rounds_reopened,
        rec.tail.is_some()
    );
    let Submit::Complete(result) = recovered.submit(key, &cs, 2, &report(2), 1, 60_000) else {
        panic!("the third report completes the recovered round");
    };
    // The never-killed reference: one in-memory table folding the same
    // three reports in the same order.
    let mut reference = CohortTable::new();
    for client in [0, 1] {
        reference.submit(key, &cs, client, &report(client), 0, 60_000);
    }
    let Submit::Complete(want) = reference.submit(key, &cs, 2, &report(2), 1, 60_000) else {
        panic!("the third report completes the in-memory round");
    };
    println!(
        "recovered estimate == uninterrupted estimate, bit for bit: {}",
        result == want
    );
    let _ = std::fs::remove_dir_all(&data_dir);
    println!();

    // ---------------------------------------------------------------
    // 13. Overload hardening & report screening. The table from (12),
    //    with the service edge's defenses on: `set_screen` validates
    //    every report *before* it touches the WAL or the accumulator —
    //    frame sizes must match the round's zero-probe, decoded values
    //    must be finite, and the distance filter quarantines reports
    //    implausibly far outside the cohort's promised ‖x‖∞ ≤ y/2 box.
    //    A screened-out report is bit-invisible: the round's estimate
    //    equals, bit for bit, a round the poison never reached. `dme
    //    serve screen=distance rate_burst=… max_resident=…` wires the
    //    same screen (plus connection caps and per-client rate limits)
    //    to TCP, and `dme exp chaos` replays a seeded hostile workload
    //    — duplicates, NaN poison, slow-loris, floods — against a live
    //    server, asserting exact honest estimates throughout.
    // ---------------------------------------------------------------
    use dme::net::screen::ScreenMode;
    use dme::quant::Message;
    let hcs = CohortSpec {
        n: 2,
        d: 8,
        spec: CodecSpec::Full,
        y: 8.0,
        seed: 7,
    };
    let hkey = CohortKey { cohort: 9, round: 0 };
    let honest = |client: usize| {
        let x = vec![1.0 + client as f64; hcs.d];
        let mut enc = cohort_codec(&hcs, hkey.round);
        let mut enc_rng = client_encoder_rng(hcs.seed, hkey.round, client);
        enc.encode(&x, &mut enc_rng)
    };
    println!("== overload hardening & screening (net::screen) ==");
    let mut hardened = CohortTable::new();
    hardened.set_screen(ScreenMode::Distance);
    assert!(matches!(
        hardened.submit(hkey, &hcs, 0, &honest(0), 0, 60_000),
        Submit::Pending { .. }
    ));
    // A NaN payload at the exact probe size: quarantined after decode,
    // never folded, never WAL'd.
    let mut bytes = Vec::new();
    for _ in 0..hcs.d {
        bytes.extend_from_slice(&f32::NAN.to_le_bytes());
    }
    let poison = Message { bits: 32 * hcs.d as u64, bytes };
    match hardened.submit(hkey, &hcs, 1, &poison, 0, 60_000) {
        Submit::Quarantined(why) => println!("NaN payload      : {why}"),
        other => println!("unexpected: {other:?}"),
    }
    // A truncated frame: shed before any decode, with a retry hint.
    let mut short = honest(1);
    short.bytes.pop();
    short.bits = 8 * short.bytes.len() as u64;
    match hardened.submit(hkey, &hcs, 1, &short, 0, 60_000) {
        Submit::Shed { reason, retry_after_ms } => {
            println!("truncated frame  : shed ({reason}), retry after {retry_after_ms}ms")
        }
        other => println!("unexpected: {other:?}"),
    }
    // The honest completion is bit-identical to a never-attacked round.
    let Submit::Complete(got) = hardened.submit(hkey, &hcs, 1, &honest(1), 0, 60_000) else {
        panic!("the second honest report completes the round");
    };
    let mut clean = CohortTable::new();
    clean.submit(hkey, &hcs, 0, &honest(0), 0, 60_000);
    let Submit::Complete(expect) = clean.submit(hkey, &hcs, 1, &honest(1), 0, 60_000) else {
        panic!("the clean round completes");
    };
    println!("attacked estimate == clean estimate, bit for bit: {}", got == expect);
    let ledger = hardened.stats()[0].screen_stats();
    println!(
        "screen ledger    : accepted={} shed={} quarantined={}",
        ledger.accepted, ledger.shed, ledger.quarantined
    );
    println!("(`dme exp chaos` runs the full hostile-workload version against a live serve)");
}
