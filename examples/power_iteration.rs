//! Distributed power iteration through the AOT runtime (Experiment 8's
//! workload as a deployable program): the partial updates
//! `u_i = X_iᵀ X_i x` are computed by the `power_update_s4096_d128` XLA
//! graph; the exchange is quantized with the Rust lattice codec; results
//! are cross-checked against the Rust-native Gram product.
//!
//! Run: `make artifacts && cargo run --release --example power_iteration`

use dme::coordinator::{CodecSpec, YPolicy};
use dme::data::gen_power_matrix;
use dme::linalg::{dist_inf, normalize};
use dme::opt::allreduce::Aggregator;
use dme::rng::Rng;

const D: usize = 128;
const S_PER: usize = 4096;
const N: usize = 2;
const Q: u32 = 64;
const ITERS: usize = 60;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let eng = dme::runtime::Engine::discover()
        .map_err(|e| format!("{e}\nhint: run `make artifacts` first"))?;
    let g_upd = eng.load("power_update_s4096_d128")?;
    println!("PJRT platform: {} — power_update graph loaded\n", eng.platform());

    let (m, v1) = gen_power_matrix(N * S_PER, D, &[10.0, 8.5, 2.0], false, 7);
    let blocks_f32: Vec<Vec<f32>> = (0..N)
        .map(|i| {
            m.data[i * S_PER * D..(i + 1) * S_PER * D]
                .iter()
                .map(|&v| v as f32)
                .collect()
        })
        .collect();
    let blocks = (0..N)
        .map(|i| m.row_block(i * S_PER, (i + 1) * S_PER))
        .collect::<Vec<_>>();

    let mut rng = Rng::new(5);
    let mut x = normalize(&rng.gaussian_vec(D));
    let mut agg = Aggregator::new(
        CodecSpec::Lq { q: Q },
        N,
        D,
        500.0, // bootstrap y; adapts from quantized points
        YPolicy::FromQuantized { slack: 2.0 },
        31,
    );
    let mut max_diff = 0.0f64;

    for it in 0..ITERS {
        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let mut us: Vec<Vec<f64>> = Vec::with_capacity(N);
        for i in 0..N {
            let out = g_upd.run_f32(&[(&blocks_f32[i], &[S_PER, D]), (&xf, &[D])])?;
            let u: Vec<f64> = out[0].iter().map(|&v| v as f64).collect();
            // Cross-check vs the Rust-native substrate.
            let native = blocks[i].gram_apply(&x);
            max_diff = max_diff.max(
                dist_inf(&u, &native) / native.iter().fold(1.0f64, |a, b| a.max(b.abs())),
            );
            us.push(u);
        }
        let rep = agg.step(&us);
        let sum = dme::linalg::scale(&rep.estimate, N as f64);
        x = normalize(&sum);
        if it % 10 == 0 || it == ITERS - 1 {
            let angle = 1.0 - dme::linalg::dot(&x, &v1).abs();
            println!(
                "iter {it:>3}  1-|<x,v1>| = {angle:.3e}   y = {:.3e}   bits/machine = {}",
                agg.y_est.y,
                rep.bits_sent[0]
            );
        }
    }
    let angle = 1.0 - dme::linalg::dot(&x, &v1).abs();
    println!("\nfinal angle error: {angle:.3e} (quantized at {} bits/coord)", 6);
    println!("max relative AOT-vs-native diff: {max_diff:.3e}");
    assert!(max_diff < 1e-3, "AOT and native Gram products must agree");
    assert!(angle < 0.05, "power iteration must converge");
    Ok(())
}
