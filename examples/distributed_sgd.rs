//! End-to-end driver: distributed quantized SGD where **all numerical
//! work runs through the AOT-compiled XLA artifacts** — gradients via the
//! `lsq_grad` graph, quantization via the Pallas `lattice_encode/decode`
//! kernels, all loaded once by the Rust PJRT runtime and executed from
//! the hot loop. Python never runs.
//!
//! Proves the three layers compose: L1 Pallas kernels inside L2 JAX
//! graphs, driven by the L3 Rust coordinator, cross-checked against the
//! Rust-native implementation every iteration.
//!
//! Run: `make artifacts && cargo run --release --example distributed_sgd`

use dme::data::gen_lsq;
use dme::linalg::{dist2, dist_inf};
use dme::quant::{CubicLattice, LatticeQuantizer, VectorCodec};
use dme::rng::{hash2, Rng};

const D: usize = 100; // model dim (lsq_grad_s4096_d100 artifact)
const DP: usize = 128; // padded dim (lattice_encode_d128_q16 artifact)
const S_PER: usize = 4096; // rows per worker
const N: usize = 2;
const Q: u32 = 16;
const ITERS: usize = 150;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let eng = dme::runtime::Engine::discover()
        .map_err(|e| format!("{e}\nhint: run `make artifacts` first"))?;
    println!("PJRT platform: {}", eng.platform());
    let g_grad = eng.load("lsq_grad_s4096_d100")?;
    let g_enc = eng.load("lattice_encode_d128_q16")?;
    let g_dec = eng.load("lattice_decode_d128_q16")?;
    println!("loaded artifacts: lsq_grad_s4096_d100, lattice_encode/decode_d128_q16\n");

    // Workload: S = 8192 synthetic least squares, rows split across 2
    // workers (static split; the AOT graph shape is per-worker).
    let ds = gen_lsq(N * S_PER, D, 2024);
    let blocks: Vec<Vec<f32>> = (0..N)
        .map(|i| {
            ds.a.data[i * S_PER * D..(i + 1) * S_PER * D]
                .iter()
                .map(|&v| v as f32)
                .collect()
        })
        .collect();
    let bvecs: Vec<Vec<f32>> = (0..N)
        .map(|i| ds.b[i * S_PER..(i + 1) * S_PER].iter().map(|&v| v as f32).collect())
        .collect();

    let mut w = vec![0.0f64; D];
    let mut y = 1.0f64; // dynamic distance estimate, §9.1 policy
    let seed = 99u64;
    let lr = 0.5;
    let mut max_native_diff = 0.0f64;
    let mut loss_log: Vec<(usize, f64, f64)> = Vec::new();

    for it in 0..ITERS {
        // --- per-worker batch gradients via the AOT lsq_grad graph.
        let wf: Vec<f32> = w.iter().map(|&v| v as f32).collect();
        let mut grads: Vec<Vec<f64>> = Vec::with_capacity(N);
        for i in 0..N {
            let out = g_grad.run_f32(&[
                (&blocks[i], &[S_PER, D]),
                (&wf, &[D]),
                (&bvecs[i], &[S_PER]),
            ])?;
            grads.push(out[0].iter().map(|&v| v as f64).collect());
        }

        // --- shared-randomness lattice for this round (both "machines"
        //     derive the identical offset from (seed, it)).
        let s = 2.0 * y / (Q as f64 - 1.0);
        let mut shared = Rng::new(hash2(seed, it as u64));
        let offset: Vec<f64> = (0..DP).map(|_| shared.uniform(-s / 2.0, s / 2.0)).collect();
        let offset_f: Vec<f32> = offset.iter().map(|&v| v as f32).collect();
        let s_arr = [s as f32];

        // --- encode worker 0's gradient with the Pallas kernel (AOT),
        //     decode at worker 1 (reference = its own gradient); and the
        //     symmetric direction. Pad d=100 → 128 with zeros.
        let mut decoded: Vec<Vec<f64>> = Vec::with_capacity(N);
        for i in 0..N {
            let me = &grads[i];
            let other = &grads[(i + 1) % N];
            let mut x_pad = vec![0.0f32; DP];
            let mut ref_pad = vec![0.0f32; DP];
            for j in 0..D {
                x_pad[j] = me[j] as f32;
                ref_pad[j] = other[j] as f32;
            }
            let enc = g_enc.run_f32(&[(&x_pad, &[DP]), (&offset_f, &[DP]), (&s_arr, &[1])])?;
            let colors = &enc[0];
            let dec = g_dec.run_f32(&[
                (colors, &[DP]),
                (&ref_pad, &[DP]),
                (&offset_f, &[DP]),
                (&s_arr, &[1]),
            ])?;
            decoded.push(dec[0][..D].iter().map(|&v| v as f64).collect());

            // Cross-check vs the Rust-native quantizer (bit-identical
            // rounding conventions — see quant::lattice docs).
            let native = LatticeQuantizer::new(
                CubicLattice::with_offset(s, offset.clone()),
                Q,
            );
            let mut other_pad = vec![0.0f64; DP];
            let mut me_pad = vec![0.0f64; DP];
            for j in 0..D {
                other_pad[j] = other[j];
                me_pad[j] = me[j];
            }
            let msg = native.clone().encode(&me_pad, &mut Rng::new(0));
            let zn = native.decode(&msg, &other_pad);
            let diff = dist_inf(&zn[..D], decoded.last().unwrap());
            max_native_diff = max_native_diff.max(diff);
        }

        // --- apply the common estimate; update y from quantized points.
        let est = dme::linalg::mean_vecs(&decoded);
        crate_apply(&mut w, -lr, &est);
        let spread = dist_inf(&decoded[0], &decoded[1]);
        if spread > 0.0 {
            y = 1.5 * spread;
        } else {
            y *= 0.5;
        }

        if it % 15 == 0 || it == ITERS - 1 {
            let loss = ds.loss(&w);
            let gerr = dist2(&est, &ds.full_gradient(&crate_sub(&w, -lr, &est))).powi(2);
            loss_log.push((it, loss, gerr));
            println!(
                "iter {it:>4}  loss {loss:.6e}  y {y:.3e}  bits/worker {}  est-err² {gerr:.3e}",
                DP * 4
            );
        }
    }

    println!("\ncross-check: max |AOT − native| over all decodes = {max_native_diff:.3e}");
    assert!(
        max_native_diff < 1e-4,
        "AOT and native paths must agree (f32 tolerance)"
    );
    let final_loss = ds.loss(&w);
    println!("final loss: {final_loss:.6e} (started near {:.3e})", ds.loss(&[0.0; D]));
    assert!(final_loss < 1e-2, "training must converge");

    // Persist the loss curve for EXPERIMENTS.md.
    let mut report = String::from("# e2e distributed SGD (AOT hot path)\niter,loss,est_err2\n");
    for (it, loss, gerr) in &loss_log {
        report += &format!("{it},{loss:.6e},{gerr:.6e}\n");
    }
    report += &format!("max_aot_native_diff,{max_native_diff:.3e}\n");
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/e2e_distributed_sgd.txt", &report).ok();
    println!("[saved results/e2e_distributed_sgd.txt]");
    Ok(())
}

fn crate_apply(w: &mut [f64], c: f64, x: &[f64]) {
    dme::linalg::axpy(w, c, x);
}

fn crate_sub(w: &[f64], c: f64, x: &[f64]) -> Vec<f64> {
    let mut out = w.to_vec();
    dme::linalg::axpy(&mut out, -c, x); // undo the step to get pre-update w
    out
}
